"""Mamba-2 SSD (state-space duality) block — chunked scan + decode recurrence.

The attention plane of the paper is inapplicable here (no QKᵀ kernel); the
paper's *criterion* still maps: the SSD state is a dynamic operand (SM
plane), the in/out projections are static weight-stationary MVMs (ReRAM
plane).  See DESIGN.md §Arch-applicability.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.modules import dense_init, rmsnorm
from repro.parallel import constrain

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# causal depthwise conv (width w) with optional streaming state
# ---------------------------------------------------------------------------

def causal_conv(x, w, b, state=None, length=None):
    """x (B, S, C); w (W, C); state (B, W-1, C) or None -> (y, new_state).

    ``length`` (traced scalar): true token count of a right-padded stream —
    the streaming state is then the last W-1 inputs *before* ``length``
    (missing ones zero), so pads never enter the state.  Conv outputs at
    positions >= length are garbage and must not be consumed.
    """
    B, S, C = x.shape
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((B, W - 1, C), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)            # (B, S+W-1, C)
    y = sum(xp[:, i:i + S] * w[i].astype(x.dtype) for i in range(W))
    if b is not None:
        y = y + b.astype(x.dtype)
    if length is None:
        new_state = xp[:, S:]                          # last W-1 inputs
    else:
        idx = length - (W - 1) + jnp.arange(W - 1)     # inputs before length
        valid = idx >= 0
        new_state = jnp.where(valid[None, :, None],
                              x[:, jnp.clip(idx, 0, S - 1)], 0)
    return y, new_state


# ---------------------------------------------------------------------------
# SSD chunked scan (Dao & Gu 2024, alg. 1 — pure jnp)
# ---------------------------------------------------------------------------

def _segsum(x):
    """x (..., q) -> (..., q, q): ss[i, j] = sum_{j<t<=i} x[t], -inf above diag."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, d, NEG_INF)


def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int, init_state=None):
    """Chunked SSD.

    x (b, l, h, p); dt (b, l, h) f32 (post-softplus); A (h,) f32 (negative);
    Bm, Cm (b, l, g, n).  Returns (y (b, l, h, p), final_state (b, h, p, n)).
    """
    b, l, h, p = x.shape
    g, n = Bm.shape[2], Bm.shape[3]
    rep = h // g
    if l % chunk:
        chunk = l  # tiny sequences: single chunk
    nc = l // chunk

    xf = (x.astype(jnp.float32) * dt[..., None]).reshape(b, nc, chunk, h, p)
    dA = (dt * A).reshape(b, nc, chunk, h)                     # (b,c,q,h)
    Bc = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2).reshape(b, nc, chunk, h, n)
    Cc = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2).reshape(b, nc, chunk, h, n)

    dA_h = dA.transpose(0, 3, 1, 2)                            # (b,h,c,q)
    dA_cs = jnp.cumsum(dA_h, axis=-1)                          # (b,h,c,q)

    # 1. intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(dA_h))                                 # (b,h,c,q,q)
    scores = jnp.einsum("bcqhn,bckhn->bhcqk", Cc, Bc)
    y_diag = jnp.einsum("bhcqk,bhcqk,bckhp->bcqhp", scores, L, xf)

    # 2. per-chunk final states
    decay = jnp.exp(dA_cs[..., -1:] - dA_cs)                   # (b,h,c,q)
    states = jnp.einsum("bckhn,bhck,bckhp->bchpn", Bc, decay, xf)

    # 3. inter-chunk recurrence over the nc chunk states
    chunk_decay = jnp.exp(dA_cs[..., -1])                      # (b,h,c)
    s0 = (jnp.zeros((b, h, p, n), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(carry, xs):
        st, cd = xs                                            # (b,h,p,n), (b,h)
        new = carry * cd[..., None, None] + st
        return new, carry                                      # emit state *before* chunk

    final, prev_states = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)         # (b,c,h,p,n)

    # 4. inter-chunk contribution
    state_decay = jnp.exp(dA_cs)                               # (b,h,c,q)
    y_off = jnp.einsum("bcqhn,bchpn,bhcq->bcqhp", Cc, prev_states, state_decay)

    y = (y_diag + y_off).reshape(b, l, h, p)
    return y.astype(x.dtype), final


def ssd_step(x, dt, A, Bm, Cm, state):
    """Single-token recurrence.  x (b,h,p); dt (b,h); Bm/Cm (b,g,n);
    state (b,h,p,n) f32 -> (y (b,h,p), new_state)."""
    b, h, p = x.shape
    g = Bm.shape[1]
    rep = h // g
    Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=1)       # (b,h,n)
    Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=1)
    dA = jnp.exp(dt * A)                                       # (b,h)
    xs = (x.astype(jnp.float32) * dt[..., None])               # (b,h,p)
    new_state = state * dA[..., None, None] + xs[..., None] * Bh[:, :, None, :]
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state


# ---------------------------------------------------------------------------
# the mamba2 block
# ---------------------------------------------------------------------------

def _dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_head_dim
    return d_inner, H, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups


def init_mamba(key, cfg, *, dtype=jnp.float32):
    d_inner, H, P, N, G = _dims(cfg)
    conv_ch = d_inner + 2 * G * N
    d_in = 2 * d_inner + 2 * G * N + H
    ks = jax.random.split(key, 4)
    dt0 = jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32)
                  * (math.log(0.1) - math.log(0.001)) + math.log(0.001))
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model, d_in), dtype),
        "conv_w": dense_init(ks[1], (cfg.conv_width, conv_ch), jnp.float32,
                             fan_in=cfg.conv_width),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt0)),                     # inv-softplus
        "norm": jnp.zeros((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[3], (d_inner, cfg.d_model), dtype, fan_in=d_inner),
    }


def init_ssm_cache(cfg, batch, dtype):
    d_inner, H, P, N, G = _dims(cfg)
    conv_ch = d_inner + 2 * G * N
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def apply_mamba(p, x, *, cfg, mode, cache=None, length=None):
    """x (B, S, D) -> (y, new_cache).

    ``length`` (prefill only, traced scalar): true prompt length of a
    right-padded stream.  Pads are masked out of the recurrence (dt = 0 →
    state passes through unchanged) and out of the conv state, so the
    prefill cache at ``length`` is exactly the unpadded one.
    """
    B, S, D = x.shape
    d_inner, H, P, N, G = _dims(cfg)
    dt_x = x.dtype

    zxbcdt = x @ p["in_proj"].astype(dt_x)
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:2 * d_inner + 2 * G * N]
    dt_raw = zxbcdt[..., -H:]

    conv_state = cache["conv"] if cache is not None and mode == "decode" else None
    xBC, new_conv = causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state,
                                length=length if mode == "prefill" else None)
    xBC = jax.nn.silu(xBC)

    x_ssm = xBC[..., :d_inner].reshape(B, S, H, P)
    Bm = xBC[..., d_inner:d_inner + G * N].reshape(B, S, G, N)
    Cm = xBC[..., d_inner + G * N:].reshape(B, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    if length is not None and mode == "prefill":
        # dt = 0 on pads: exp(dt*A) = 1 and dt*x = 0 — identity update
        dt = jnp.where((jnp.arange(S) < length)[None, :, None], dt, 0.0)
    A = -jnp.exp(p["A_log"])

    if mode == "decode":
        y, new_state = ssd_step(x_ssm[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0],
                                cache["state"])
        y = y[:, None]
        new_cache = {"conv": new_conv, "state": new_state}
    else:
        init_state = None
        y, final_state = ssd_scan(x_ssm, dt, A, Bm, Cm, chunk=cfg.ssm_chunk,
                                  init_state=init_state)
        new_cache = None
        if mode == "prefill":
            new_cache = {"conv": new_conv, "state": final_state}

    y = y + x_ssm * p["D"][None, None, :, None].astype(dt_x)
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    y = constrain(y, "act_ff")
    return y @ p["out_proj"].astype(dt_x), new_cache
