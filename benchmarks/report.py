"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from
experiments/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.report > /tmp/tables.md

A malformed or partially-written results file (an interrupted benchmark
run, a truncated CI artifact) is skipped with a warning on stderr — the
report still renders every healthy section.
"""
import glob
import json
import os
import sys

from repro.config import ASSIGNED_ARCHS, SHAPES

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def _warn(msg: str) -> None:
    print(f"# WARNING: {msg}", file=sys.stderr)


def _load_json(path: str):
    """Parse one results JSON; None (with a warning) when the file is
    malformed / truncated instead of aborting the whole report."""
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        _warn(f"skipping {os.path.normpath(path)}: {e}")
        return None


def load():
    recs = {}
    for f in glob.glob(os.path.join(DRYRUN, "*.json")):
        r = _load_json(f)
        if r is None:
            continue
        try:
            recs[(r["arch"], r["shape"], r["mesh"])] = r
        except (KeyError, TypeError) as e:
            _warn(f"skipping {os.path.normpath(f)}: missing key {e}")
    return recs


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def dryrun_table(recs) -> str:
    out = ["| arch | shape | mesh | status | compile_s | live GiB (TPU-true) | fits | HLO GFLOP/dev | wire GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            for mesh in ("single", "multi"):
                r = recs.get((arch, shape, mesh))
                if r is None:
                    out.append(f"| {arch} | {shape} | {mesh} | MISSING | | | | | |")
                    continue
                if r["status"] == "skipped":
                    out.append(f"| {arch} | {shape} | {mesh} | skip: "
                               f"{r['reason'][:60]}… | | | | | |")
                    continue
                m, rl = r["memory"], r["roofline"]
                live = m.get("live_bytes_tpu", m["live_bytes"])
                out.append(
                    f"| {arch} | {shape} | {mesh} | ok | {r['t_compile_s']} | "
                    f"{fmt_bytes(live)} | {'✓' if m['fits_v5e'] else '✗'} | "
                    f"{rl['hlo_flops_per_dev']/1e9:.0f} | "
                    f"{rl['wire_bytes_per_dev']/2**30:.2f} |")
    return "\n".join(out)


def roofline_table(recs) -> str:
    out = ["| arch | shape | compute_s | memory_s | collective_s | bound | "
           "step_s | roofline_frac | useful_ratio | what moves the bound |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    hints = {
        "compute": "more chips / lower-precision matmuls",
        "memory": "flash-attention kernel keeps score tensors in VMEM; "
                  "int8 weights (pim_mvm) halve weight streaming",
        "collective": "replicate GQA KV heads instead of seq-sharding "
                      "(kills per-layer KV all-gathers); overlap via "
                      "collective matmul",
    }
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            r = recs.get((arch, shape, "single"))
            if r is None or r["status"] != "ok":
                continue
            rl = r["roofline"]
            out.append(
                f"| {arch} | {shape} | {rl['compute_s']:.3e} | "
                f"{rl['memory_s']:.3e} | {rl['collective_s']:.3e} | "
                f"{rl['bottleneck']} | {rl['step_s']:.3e} | "
                f"{rl['roofline_frac']:.3f} | {rl['useful_ratio']:.2f} | "
                f"{hints[rl['bottleneck']]} |")
    return "\n".join(out)


def summary(recs) -> str:
    ok = [r for r in recs.values() if r["status"] == "ok"]
    skip = [r for r in recs.values() if r["status"] == "skipped"]
    fit = [r for r in ok if r["memory"]["fits_v5e"]]
    worst = sorted(ok, key=lambda r: r["roofline"]["roofline_frac"])[:5]
    lines = [
        f"- cells: {len(ok)} ok + {len(skip)} documented skips "
        f"= {len(ok)+len(skip)} / 80",
        f"- fits 16 GiB v5e HBM (TPU-true liveness): {len(fit)}/{len(ok)}",
        "- worst roofline fractions (hillclimb candidates): "
        + ", ".join(f"{r['arch']}/{r['shape']}/{r['mesh']}"
                    f"({r['roofline']['roofline_frac']:.2f})" for r in worst),
    ]
    return "\n".join(lines)


def serving_table() -> str:
    """Render experiments/BENCH_serving.json (benchmarks.perf_serving)."""
    path = os.path.normpath(os.path.join(DRYRUN, "..", "BENCH_serving.json"))
    if not os.path.exists(path):
        return "(no BENCH_serving.json — run `python -m benchmarks.perf_serving`)"
    r = _load_json(path)
    if r is None:
        return "(BENCH_serving.json is malformed — re-run `python -m benchmarks.perf_serving`)"
    out = [f"config: {r['arch']} (reduced) · backend={r['backend']} · "
           f"slots={r['max_batch']} · kv_len={r['kv_len']} · "
           f"prompt={r['prompt_len']} · max_new={r['max_new_tokens']} · "
           f"requests={r['requests']}"
           + (" · SMOKE" if r.get("smoke") else ""),
           "",
           "| path | impl | chunk | engine tok/s | step ms | d2h B/token |",
           "|---|---|---|---|---|---|"]
    for name, row in r["results"].items():
        out.append(
            f"| {name} | {row['impl']} | {row['decode_chunk']} | "
            f"{row['tokens_per_s']:.0f} | {row['step_ms']:.3f} | "
            f"{row['host_bytes_per_token']:.1f} |")
    out.append("")
    out.append(f"fused / seed engine throughput: "
               f"**{r['speedup_fused_vs_seed']:.2f}×**")

    ps = r.get("prefill_shape", {})
    out += ["",
            "#### Prefill admission (packed ragged + chunked vs sequential)",
            "",
            f"kv_len={ps.get('kv_len')} · chunk={ps.get('chunk')} · "
            f"{ps.get('requests')} requests × {ps.get('prompt_len')} tok "
            f"(+{ps.get('long_count')} × {ps.get('long_len')} tok in the "
            f"long workload) · max_new={ps.get('max_new_tokens')}",
            "",
            "| workload | path | prefill tok/s | mean TTFT ms | calls | "
            "max stall (tok) |",
            "|---|---|---|---|---|---|"]
    for section in ("prefill", "prefill_long"):
        for name, row in r.get(section, {}).items():
            out.append(
                f"| {section} | {name} | {row['prefill_tokens_per_s']:.0f} | "
                f"{row['mean_ttft_s']*1e3:.1f} | {row['prefill_calls']} | "
                f"{row['max_stall_tokens']} |")
    out.append("")
    out.append(f"packed / sequential prefill throughput: "
               f"**{r['speedup_packed_vs_seq_prefill']:.2f}×**")
    return "\n".join(out)


def cosim_table() -> str:
    """Render experiments/BENCH_cosim.json (benchmarks.perf_cosim)."""
    path = os.path.normpath(os.path.join(DRYRUN, "..", "BENCH_cosim.json"))
    if not os.path.exists(path):
        return "(no BENCH_cosim.json — run `python -m benchmarks.perf_cosim`)"
    r = _load_json(path)
    if r is None:
        return "(BENCH_cosim.json is malformed — re-run `python -m benchmarks.perf_cosim`)"
    out = [f"chiplets={r['chiplets']} · prompt={r['prompt_len']} · "
           f"gen={r['gen_len']} · batch={r.get('batch', 1)}"
           + (" · SMOKE" if r.get("smoke") else ""),
           "",
           "| model | system | TTFT ms | decode ms/step | decode tok/s | "
           "batch uplift | E/tok mJ | decode traffic |",
           "|---|---|---|---|---|---|---|---|"]
    for name, m in r["models"].items():
        for arch, row in m["archs"].items():
            out.append(
                f"| {name} | {arch} | {row['ttft_ms']:.0f} | "
                f"{row['decode_step_ms']:.2f} | {row['decode_tok_s']:.0f} | "
                f"{row.get('batch_uplift', 1):.2f}× | "
                f"{row['energy_per_token_mj']:.0f} | "
                f"{row['decode_traffic_frac']*100:.1f}% |")
    gains = [(n, m["ttft_gain"], m["decode_gain"], m["energy_gain"])
             for n, m in r["models"].items()]
    out += ["",
            "2.5D-HI vs best chiplet baseline: "
            + "; ".join(f"{n} **{t:.1f}×** TTFT / **{d:.1f}×** decode / "
                        f"**{e:.1f}×** E/tok" for n, t, d, e in gains)]
    sweep = r.get("noi_sweep")
    if sweep:
        out += ["",
                "#### Decode-aware NoI Pareto sweep "
                f"(batch={sweep['batch']}, {sweep['iterations']} MOO iters × "
                f"{sweep['ls_steps']} ls-steps, vs placement-unaware mesh "
                "= 1.0)",
                "",
                "| model | chiplets | Pareto pts | decode-aware μ/σ | "
                "single-pass design μ/σ (gen traffic) | μ gain |",
                "|---|---|---|---|---|---|"]
        same = 0
        for c in sweep["cells"]:
            same += bool(c.get("same_design"))
            out.append(
                f"| {c['model']} | {c['chiplets']} | {len(c['front'])} | "
                f"{c['best_mu_norm']:.3f}/{c['best_sigma_norm']:.3f} | "
                f"{c['single_pass_mu_norm']:.3f}/"
                f"{c['single_pass_sigma_norm']:.3f} | "
                f"{c['gain_mu']:.2f}×"
                + (" (=)" if c.get("same_design") else "") + " |")
        if same:
            out += ["",
                    f"(=) in {same}/{len(sweep['cells'])} cells both "
                    "same-seed searches converged to the identical "
                    "placement — a 1.00× gain there means the searches "
                    "coincided, not that decode-awareness is free"]
    qs = r.get("quant_sweep")
    if qs:
        out += ["",
                "#### Quantised-vs-fp precision sweep "
                f"(batch={qs['batch']}, NoI on {', '.join(qs['noi_models'])})",
                "",
                "| model | bits | decode ms/step | decode GiB | traffic ÷ | "
                "step × | NoI μ (quant-designed / fp-designed) |",
                "|---|---|---|---|---|---|---|"]
        for c in qs["cells"]:
            noi = c.get("noi")
            noi_s = (f"{noi['best_mu_norm']:.3f} / "
                     f"{noi['fp_design_mu_norm']:.3f}"
                     + (" (=)" if noi.get("same_design") else "")
                     ) if noi else "—"
            out.append(
                f"| {c['model']} | w{c['weight_bits']}kv{c['kv_bits']} | "
                f"{c['decode_step_ms']:.2f} | {c['decode_gb']:.2f} | "
                f"{c['decode_traffic_reduction_vs_fp']:.2f}× | "
                f"{c['decode_step_speedup_vs_fp']:.2f}× | {noi_s} |")
    br = r.get("bridge")
    if br:
        mix = br["mix"]
        hi_b = br["archs"]["2.5D-HI"]
        out += ["",
                f"engine bridge: {br['arch']} ({br['backend']}) served "
                f"{mix['requests']} requests "
                f"({mix['prefill_tokens']} prefill + {mix['decode_tokens']} "
                f"decode tok, chunk={mix['prefill_chunk']}, mean active "
                f"slots {mix.get('mean_active_slots', 0):.1f}/"
                f"{mix['max_batch']}) → 2.5D-HI "
                f"{hi_b['tokens_per_s']:.0f} tok/s at the measured "
                f"batch={hi_b.get('batch', 1)}"
                + (f" vs {br['archs_batch1']['2.5D-HI']['tokens_per_s']:.0f} "
                   f"tok/s single-streamed"
                   if "archs_batch1" in br else "")
                + ", projected on the full model"]
    return "\n".join(out)


def quant_table() -> str:
    """Render experiments/BENCH_quant.json (benchmarks.perf_quant)."""
    path = os.path.normpath(os.path.join(DRYRUN, "..", "BENCH_quant.json"))
    if not os.path.exists(path):
        return "(no BENCH_quant.json — run `python -m benchmarks.perf_quant`)"
    r = _load_json(path)
    if r is None:
        return "(BENCH_quant.json is malformed — re-run `python -m benchmarks.perf_quant`)"
    out = [f"config: {r['arch']} (reduced) · backend={r['backend']} · "
           f"impl={r.get('impl', 'ref')} · slots={r['max_batch']} · "
           f"kv_len={r['kv_len']} · prompt={r['prompt_len']} · "
           f"max_new={r['max_new_tokens']} · requests={r['requests']}"
           + (" · SMOKE" if r.get("smoke") else ""),
           "",
           "| variant | bits (w/kv) | tok/s | step ms | exact parity | "
           "prefix parity | prefill max|Δ| | decode max|Δ| |",
           "|---|---|---|---|---|---|---|---|"]
    for name, row in r["results"].items():
        d = r["drift"][name]
        out.append(
            f"| {name} | {row['weight_bits'] or 'fp'}/"
            f"{row['kv_bits'] or 'fp'} | {row['tokens_per_s']:.0f} | "
            f"{row['step_ms']:.3f} | {row['exact_parity']:.2f} | "
            f"{row['prefix_parity']:.2f} | {d['prefill_max_abs']:.3g} | "
            f"{d['decode_max_abs']:.3g} |")
    out += ["",
            f"fake-quant oracle parity (w8 vs fp engine on "
            f"dequant(quant(W))): **{r['fakequant_parity_w8']:.2f}** "
            "(must be 1.00 — the weight path changes values, not arithmetic)"]
    ps = r.get("planeb_shape", {})
    out += ["",
            f"#### Plane-B projection ({r['arch']} full dims, "
            f"{ps.get('chiplets')} chiplets, prompt={ps.get('prompt_len')}, "
            f"gen={ps.get('gen_len')}, batch={ps.get('batch')})",
            "",
            "| bits (w/kv) | decode GiB | weight-stream GiB | "
            "decode ms/step | traffic ÷ vs fp |",
            "|---|---|---|---|---|"]
    for row in r.get("planeb", []):
        out.append(
            f"| {row['weight_bits']}/{row['kv_bits']} | "
            f"{row['decode_gb']:.2f} | {row['weight_stream_gb']:.2f} | "
            f"{row['decode_step_ms']:.2f} | "
            f"{row['decode_traffic_reduction_vs_fp']:.2f}× |")
    return "\n".join(out)


def resilience_table() -> str:
    """Render experiments/BENCH_resilience.json (benchmarks.perf_resilience)."""
    path = os.path.normpath(os.path.join(DRYRUN, "..",
                                         "BENCH_resilience.json"))
    if not os.path.exists(path):
        return ("(no BENCH_resilience.json — run "
                "`python -m benchmarks.perf_resilience`)")
    r = _load_json(path)
    if r is None:
        return ("(BENCH_resilience.json is malformed — re-run "
                "`python -m benchmarks.perf_resilience`)")
    out = [f"chiplets={r['chiplets']} · prompt={r['prompt_len']} · "
           f"gen={r['gen_len']} · batch={r.get('batch', 1)}"
           + (" · SMOKE" if r.get("smoke") else "")]

    zf = (r.get("zoo_faults") or {}).get("cells") or []
    if zf:
        out += ["",
                "| model | k links down | scenarios | disconnected | "
                "worst TTFT ms | worst decode ms | worst decode × |",
                "|---|---|---|---|---|---|---|"]
        for c in zf:
            infl = c.get("decode_inflation_worst")
            out.append(
                f"| {c['model']} | {c['k']} | {c['n_scenarios']} | "
                f"{c['n_disconnected']} | "
                f"{_opt(c.get('ttft_ms_worst'), '{:.0f}')} | "
                f"{_opt(c.get('decode_step_ms_worst'), '{:.2f}')} | "
                f"{_opt(infl, '{:.2f}×')} |")
    else:
        out += ["", "(zoo_faults section missing from the record)"]

    cells = (r.get("noi_fault_search") or {}).get("cells") or []
    if cells:
        out += ["",
                "#### Fault-aware vs fault-oblivious NoI designs "
                "(worst-case degradation under every single-link failure)",
                "",
                "| model | oblivious worst k=1 | (disc) | aware worst k=1 "
                "| (disc) | gain | aware survives k=1 |",
                "|---|---|---|---|---|---|---|"]
        for c in cells:
            o, a = c.get("oblivious", {}), c.get("aware", {})
            gain = c.get("gain_worst_k1")
            out.append(
                f"| {c['model']} | "
                f"{_opt(o.get('degradation_k1'), '{:.3f}×')} | "
                f"{o.get('n_disconnected_k1', '?')} | "
                f"{_opt(a.get('degradation_k1'), '{:.3f}×')} | "
                f"{a.get('n_disconnected_k1', '?')} | "
                f"{'∞' if gain is None else f'{gain:.2f}×'} | "
                f"{'yes' if c.get('aware_survives_k1') else 'NO'} |")
    else:
        out += ["", "(noi_fault_search section missing from the record)"]

    ov = (r.get("engine_overload") or {}).get("rows") or []
    if ov:
        meta = r.get("engine_overload", {})
        out += ["",
                f"#### Engine overload (burst={meta.get('burst')} on "
                f"{meta.get('max_batch')} slots · "
                f"deadline={_opt(meta.get('deadline_ms'), '{:.0f}')} ms · "
                f"queue cap={meta.get('max_queue')})",
                "",
                "| policy | done | rejected | missed deadline | "
                "goodput tok/s |",
                "|---|---|---|---|---|"]
        for row in ov:
            out.append(
                f"| {row['policy']} | {row['done']}/{row['submitted']} | "
                f"{row['rejected']} | {row['failed_deadline']} | "
                f"{row['goodput_tok_s']:.0f} |")
    else:
        out += ["", "(engine_overload section missing from the record)"]
    return "\n".join(out)


def recovery_table() -> str:
    """Render experiments/BENCH_recovery.json (benchmarks.perf_recovery)."""
    path = os.path.normpath(os.path.join(DRYRUN, "..",
                                         "BENCH_recovery.json"))
    if not os.path.exists(path):
        return ("(no BENCH_recovery.json — run "
                "`python -m benchmarks.perf_recovery`)")
    r = _load_json(path)
    if r is None:
        return ("(BENCH_recovery.json is malformed — re-run "
                "`python -m benchmarks.perf_recovery`)")
    out = [f"chiplets={r['chiplets']} · prompt={r['prompt_len']} · "
           f"gen={r['gen_len']} · batch={r.get('batch', 1)}"
           + (" · SMOKE" if r.get("smoke") else "")]

    cells = (r.get("chaos") or {}).get("cells") or []
    if cells:
        out += ["",
                "| model | kv bits | kill points (kind@iter) | exactly-once "
                "| ckpts written | restores | replayed |",
                "|---|---|---|---|---|---|---|"]
        for c in cells:
            if not c.get("supported", True):
                out.append(f"| {c['model']} | — | engine-unsupported "
                           f"(enc-dec) | n/a | | | |")
                continue
            kills = c.get("kills") or []
            exact = all(k["match"] and not k["lost"] and not k["duplicated"]
                        for k in kills)
            out.append(
                f"| {c['model']} | {c.get('kv_bits') or 'fp'} | "
                + " ".join(f"{k['kind']}@{k['kill_at']}" for k in kills)
                + f" | {'yes' if exact else 'NO'} | "
                f"{sum(k['checkpoints_written'] for k in kills)} | "
                f"{sum(k['restores'] for k in kills)} | "
                f"{sum(k['replayed_requests'] for k in kills)} |")
    else:
        out += ["", "(chaos section missing from the record)"]

    cells = (r.get("mttr_noi_search") or {}).get("cells") or []
    if cells:
        out += ["",
                "#### MTTR-aware vs fault-oblivious NoI designs "
                "(worst-case service + recovery under every single "
                "chiplet loss)",
                "",
                "| model | oblivious worst s | (disc) | aware worst s | "
                "(disc) | ckpt stream overhead | gain | "
                "aware survives k=1 |",
                "|---|---|---|---|---|---|---|---|"]
        for c in cells:
            o, a = c.get("oblivious", {}), c.get("aware", {})
            gain = c.get("gain_worst_k1")
            out.append(
                f"| {c['model']} | "
                f"{_opt(o.get('worst_total_k1'), '{:.4f}')} | "
                f"{o.get('n_disconnected_k1', '?')} | "
                f"{_opt(a.get('worst_total_k1'), '{:.4f}')} | "
                f"{a.get('n_disconnected_k1', '?')} | "
                f"{_opt(a.get('ckpt_overhead'), '{:.4f}×')} | "
                f"{'∞' if gain is None else f'{gain:.3f}×'} | "
                f"{'yes' if c.get('aware_survives_k1') else 'NO'} |")
    else:
        out += ["", "(mttr_noi_search section missing from the record)"]
    return "\n".join(out)


def capacity_table() -> str:
    """Render experiments/BENCH_capacity.json (benchmarks.perf_capacity)."""
    path = os.path.normpath(os.path.join(DRYRUN, "..", "BENCH_capacity.json"))
    if not os.path.exists(path):
        return ("(no BENCH_capacity.json — run "
                "`python -m benchmarks.perf_capacity`)")
    r = _load_json(path)
    if r is None:
        return ("(BENCH_capacity.json is malformed — re-run "
                "`python -m benchmarks.perf_capacity`)")
    e = r.get("engine", {})
    slo = r.get("slo", {})
    out = [f"backend={r['backend']} · slots={e.get('max_batch')} · "
           f"kv_len={e.get('kv_len')} · max_new={e.get('max_new_tokens')} · "
           f"{r['requests']} req/point · hi class = {r['hi_fraction']:.0%} "
           f"of traffic @ TTFT≤{slo.get('hi_ttft_ms', 0):.0f} ms"
           + (" · SMOKE" if r.get("smoke") else ""),
           "",
           "| model | sched | load | offered req/s | hi TTFT p50/p99 ms | "
           "lo TTFT p99 ms | hi TPOT p99 ms |",
           "|---|---|---|---|---|---|---|"]
    for arch, m in r["models"].items():
        for sched in r["schedulers"]:
            for pt in m["curves"][sched]:
                hi, lo = pt["classes"]["hi"], pt["classes"]["lo"]
                # percentile fields are None (not 0.0) when a class saw no
                # finished requests / no multi-token requests at this load
                # point — render "—", never a fake 0 ms latency
                out.append(
                    f"| {arch} | {sched} | {pt['load_x']:g}× | "
                    f"{pt['offered_rps']:.0f} | "
                    f"{_ms(hi.get('ttft_p50_s'), '{:.1f}')} / "
                    f"{_ms(hi.get('ttft_p99_s'), '{:.1f}')} | "
                    f"{_ms(lo.get('ttft_p99_s'), '{:.1f}')} | "
                    f"{_ms(hi.get('tpot_p99_s'), '{:.2f}')} |")
    out.append("")
    for arch, m in r["models"].items():
        hp = m["hi_p99_ttft_s"]
        verdict = "**SLO wins**" if m["slo_wins_hi_p99_ttft"] else "no win"
        out.append(
            f"- {arch}: capacity {m['capacity_rps']:.0f} req/s · overload "
            f"hi-class p99 TTFT {_ms(hp.get('fifo'), '{:.0f}')} ms (fifo) → "
            f"{_ms(hp.get('slo'), '{:.0f}')} ms (slo) — {verdict}")
    out.append("")
    out.append("Overload mix → Plane-B co-sim (SLO run, measured episode "
               "mix through `cosim_from_engine`):")
    out.append("")
    out.append("| model | arch | TTFT ms | tok/s | mJ/token |")
    out.append("|---|---|---|---|---|")
    for arch, m in r["models"].items():
        for noi, g in m["cosim"]["archs"].items():
            out.append(f"| {arch} | {noi} | {g['ttft_s']*1e3:.2f} | "
                       f"{g['tokens_per_s']:.0f} | "
                       f"{g['energy_per_token_j']*1e3:.2f} |")
    return "\n".join(out)


def spec_table() -> str:
    """Render experiments/BENCH_spec.json (benchmarks.perf_spec)."""
    path = os.path.normpath(os.path.join(DRYRUN, "..", "BENCH_spec.json"))
    if not os.path.exists(path):
        return ("(no BENCH_spec.json — run "
                "`python -m benchmarks.perf_spec`)")
    r = _load_json(path)
    if r is None:
        return ("(BENCH_spec.json is malformed — re-run "
                "`python -m benchmarks.perf_spec`)")
    out = [f"backend={r['backend']} · {r['arch']} (reduced) · "
           f"slots={r.get('max_batch')} · kv_len={r.get('kv_len')} · "
           f"max_new={r.get('max_new_tokens')}"
           + (" · SMOKE" if r.get("smoke") else ""),
           "",
           "| variant | k | draft bits | tok/s | decode steps | exact | "
           "acceptance | tok/weight-stream |",
           "|---|---|---|---|---|---|---|---|"]
    for name, v in r["results"].items():
        out.append(
            f"| {name} | {v['spec_k']} | {v['spec_draft_bits']} | "
            f"{v['tokens_per_s']:.0f} | {v['decode_steps']} | "
            f"{v['exact_parity']:.2f} | "
            f"{_opt(v.get('spec_acceptance'), '{:.3f}')} | "
            f"{_opt(v.get('spec_tokens_per_step'), '{:.2f}')} |")
    out += ["", "Acceptance sweep (full-size, fabric GB per committed "
            "token — one k=4 int8-draft step amortised over E[tokens]):",
            "",
            "| acceptance | E[tok/step] | GB/token | vs plain decode |",
            "|---|---|---|---|"]
    for row in r["planeb_sweep"]:
        out.append(f"| {row['acceptance']:.2f} | "
                   f"{row['tokens_per_step']:.2f} | "
                   f"{row['gb_per_token']:.3f} | "
                   f"{row['reduction_vs_plain']:.2f}× |")
    out += ["", "NoI search on the measured mixes (same seeded budget):", ""]
    for name, v in r["noi"].items():
        out.append(
            f"- {name}: fabric {v['fabric_gb_per_token']:.3f} GB/token · "
            f"best μ {_opt(v.get('best_mu'), '{:.3f}')} · "
            f"front size {len(v['front'])}")
    return "\n".join(out)


def calib_table() -> str:
    """Render experiments/BENCH_calib.json (benchmarks.perf_calib): the
    fitted per-phase cost models, the measured-vs-analytical gap, and the
    calibration error bar attached to every co-sim headline."""
    path = os.path.normpath(os.path.join(DRYRUN, "..", "BENCH_calib.json"))
    if not os.path.exists(path):
        return "(no BENCH_calib.json — run `python -m benchmarks.perf_calib`)"
    r = _load_json(path)
    if r is None:
        return ("(BENCH_calib.json is malformed — re-run "
                "`python -m benchmarks.perf_calib`)")
    bar = r["error_bar_rel"]
    out = [f"backend={r['backend']} · interpret={r['interpret']} · "
           f"{r['n_samples']} samples · pinned tolerance "
           f"{r['tolerance_rel']}"
           + (" · SMOKE" if r.get("smoke") else ""),
           "",
           "| phase | plane | term | rate/s | launch µs | rate ±CI95 | r² | "
           "held-out max err | log₁₀(meas/analytical) |",
           "|---|---|---|---|---|---|---|---|---|"]
    fits = r["table"]["fits"]
    for kind, e in r["phase_errors"].items():
        f = fits[kind]
        out.append(
            f"| {kind} | {e['plane']} | {e['term']} | {f['rate']:.2e} | "
            f"{f['intercept_s'] * 1e6:.1f} | "
            f"{_opt(f.get('rate_ci95_rel'), '±{:.0%}')} | {f['r2']:.3f} | "
            f"{f['heldout_max_rel_err']:.3f} | "
            f"{e['log10_measured_over_analytical']:+.2f} |")
    cal = r["calib"]
    out += ["",
            f"measured calib (opt-in, default analytical path untouched): "
            f"sm_efficiency {cal['default']['sm_efficiency']:.2e} → "
            f"{cal['measured']['sm_efficiency']:.2e} · reram_fill "
            f"{cal['default']['reram_fill']:.2e} → "
            f"{cal['measured']['reram_fill']:.2e}"]
    c = r["cosim"]
    out += [f"replay ({c['model']}, {c['chiplets']} chiplets): decode "
            f"{c['default']['decode_step_ms']:.2f} ms/step analytical vs "
            f"{c['measured']['decode_step_ms']:.2f} ms/step under the "
            f"measured ({r['backend']}) rates "
            f"({c['decode_step_rel_delta']:+.1%})"]
    tr = r["engine_trace"]
    out += [f"engine trace: {tr['trace_iterations']} iterations · decode "
            f"step {_ms(tr.get('trace_decode_step_s'), '{:.2f}')} ms mean / "
            f"{_ms(tr.get('trace_decode_step_p95_s'), '{:.2f}')} ms p95 · "
            f"prefill {tr['trace_prefill_s'] * 1e3:.0f} ms · d2h "
            f"{tr['trace_d2h_s'] * 1e3:.0f} ms total"]
    # every co-sim headline gets the calibration error bar: the worst
    # held-out residual of any fitted phase bounds how literally the
    # analytical ms/step numbers should be read
    cpath = os.path.normpath(os.path.join(DRYRUN, "..", "BENCH_cosim.json"))
    cr = _load_json(cpath) if os.path.exists(cpath) else None
    if cr:
        rows = []
        for name, m in cr["models"].items():
            hi = m["archs"]["2.5D-HI"]
            step = hi["decode_step_ms"]
            rows.append(f"{name} {step:.2f} ±{step * bar:.2f}")
        out += ["",
                f"co-sim decode ms/step headlines ± calibration error bar "
                f"(±{bar:.0%}): " + "; ".join(rows)]
    else:
        out += ["", f"calibration error bar ±{bar:.0%} (no BENCH_cosim.json "
                "to qualify — run `python -m benchmarks.perf_cosim`)"]
    return "\n".join(out)


def _opt(v, fmt: str) -> str:
    """Format an optional number ('—' for the None a disconnected or
    unroutable sweep records)."""
    return "—" if v is None else fmt.format(v)


def _ms(v, fmt: str) -> str:
    """Format an optional seconds value as milliseconds ('—' when the
    sample class was empty and the record holds null)."""
    return "—" if v is None else fmt.format(v * 1e3)


def _render(fn, *args) -> str:
    """One report section; a record that parses but is missing keys (an
    older schema, a half-migrated run) degrades to a warning line instead
    of killing every section after it."""
    try:
        return fn(*args)
    except (KeyError, TypeError, AttributeError) as e:
        _warn(f"section {fn.__name__} failed to render: {e!r}")
        return f"(section unavailable — malformed record: {e!r})"


def main():
    # a checkout with no experiments/ at all (fresh clone, CI before the
    # first artifact lands) must still render: every section degrades to
    # its own "missing" line, and the dry-run glob on a missing dir is
    # simply empty
    if not os.path.isdir(os.path.normpath(os.path.join(DRYRUN, ".."))):
        _warn("experiments/ directory missing — rendering placeholders")
    recs = load()
    print("### Dry-run matrix (40 cells × 2 meshes)\n")
    print(_render(summary, recs) + "\n")
    print(_render(dryrun_table, recs) + "\n")
    print("### Roofline (single-pod, per §Roofline)\n")
    print(_render(roofline_table, recs) + "\n")
    print("### Serving decode fast path (benchmarks.perf_serving)\n")
    print(_render(serving_table) + "\n")
    print("### Capacity: tail latency vs offered load per scheduler "
          "(benchmarks.perf_capacity)\n")
    print(_render(capacity_table) + "\n")
    print("### Generation co-simulation (benchmarks.perf_cosim)\n")
    print(_render(cosim_table) + "\n")
    print("### Measured-cost calibration (benchmarks.perf_calib)\n")
    print(_render(calib_table) + "\n")
    print("### Quantised serving (benchmarks.perf_quant)\n")
    print(_render(quant_table) + "\n")
    print("### Speculative decoding (benchmarks.perf_spec)\n")
    print(_render(spec_table) + "\n")
    print("### Resilience under faults and overload "
          "(benchmarks.perf_resilience)\n")
    print(_render(resilience_table) + "\n")
    print("### Crash recovery: chaos kill+restore and MTTR-aware NoI "
          "(benchmarks.perf_recovery)\n")
    print(_render(recovery_table))


if __name__ == "__main__":
    main()
