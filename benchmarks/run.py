"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig8 fig10 # subset
"""
import sys
import time
import traceback

MODULES = [
    ("fig4", "benchmarks.fig4_pareto"),
    ("fig8", "benchmarks.fig8_kernels"),
    ("fig9", "benchmarks.fig9_scale64"),
    ("fig10", "benchmarks.fig10_scale100"),
    ("table4", "benchmarks.table4_absolute"),
    ("fig11", "benchmarks.fig11_thermal"),
    ("sec44", "benchmarks.sec44_endurance"),
    ("kernels", "benchmarks.kernel_micro"),
]


def main() -> None:
    want = set(sys.argv[1:])
    failed = []
    for key, modname in MODULES:
        if want and key not in want:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            mod.run(verbose=True)
            print(f"# {key}: PASS ({time.time() - t0:.1f}s)\n", flush=True)
        except Exception:
            failed.append(key)
            print(f"# {key}: FAIL\n{traceback.format_exc()}", flush=True)
    if failed:
        raise SystemExit(f"failed: {failed}")
    print("# all benchmarks passed")


if __name__ == "__main__":
    main()
