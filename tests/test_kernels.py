"""Per-kernel correctness: shape/dtype sweeps, Pallas (interpret mode) vs
the pure-jnp ref.py oracle (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.pim_mvm.ops import pim_mvm, quantize_weights
from repro.kernels.pim_mvm.ref import dequantize_ref, pim_mvm_ref


def _qkv(key, B, Sq, Skv, Hq, Hkv, hd, hdv=None, dtype=jnp.float32):
    hdv = hdv or hd
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, Sq, Hq, hd), dtype)
    k = jax.random.normal(k2, (B, Skv, Hkv, hd), dtype)
    v = jax.random.normal(k3, (B, Skv, Hkv, hdv), dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,Hq,Hkv,hd", [
    (1, 128, 4, 4, 64),     # MHA
    (2, 128, 4, 2, 64),     # GQA
    (1, 256, 8, 1, 32),     # MQA
    (2, 64, 4, 4, 128),     # larger head dim
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(B, S, Hq, Hkv, hd, causal):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, S, Hq, Hkv, hd)
    out = attention(q, k, v, causal=causal, impl="pallas_interpret")
    ref = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_sliding_window(window):
    q, k, v = _qkv(jax.random.PRNGKey(1), 2, 128, 128, 4, 2, 32)
    out = attention(q, k, v, causal=True, window=window,
                    impl="pallas_interpret")
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_softcap():
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 128, 128, 4, 4, 32)
    out = attention(q, k, v, causal=True, softcap=50.0,
                    impl="pallas_interpret")
    ref = attention_ref(q, k, v, causal=True, softcap=50.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16():
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 128, 128, 4, 4, 64,
                   dtype=jnp.bfloat16)
    out = attention(q, k, v, causal=True, impl="pallas_interpret")
    ref = attention_ref(q, k, v, causal=True)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        atol=3e-2, rtol=3e-2)


def test_flash_nonsquare_blocks():
    """Sq != Skv (cross-attention-like) + uneven block split."""
    q, k, v = _qkv(jax.random.PRNGKey(4), 1, 128, 256, 4, 4, 32)
    out = flash_attention_fwd(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=False, block_q=64, block_k=128,
        interpret=True).transpose(0, 2, 1, 3)
    ref = attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_causal_no_future_leak():
    """Perturbing future tokens must not change past outputs."""
    q, k, v = _qkv(jax.random.PRNGKey(5), 1, 128, 128, 2, 2, 32)
    out1 = attention(q, k, v, causal=True, impl="pallas_interpret")
    k2 = k.at[:, 64:].set(jax.random.normal(jax.random.PRNGKey(9),
                                            k[:, 64:].shape))
    v2 = v.at[:, 64:].set(0.0)
    out2 = attention(q, k2, v2, causal=True, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out1[:, :64]),
                               np.asarray(out2[:, :64]), atol=1e-6)


def test_ref_ring_buffer_positions():
    """Explicit kv positions (ring-buffer decode) match a gather-based mask."""
    key = jax.random.PRNGKey(6)
    B, Skv, H, hd = 2, 32, 2, 16
    q = jax.random.normal(key, (B, 1, H, hd))
    k = jax.random.normal(key, (B, Skv, H, hd))
    v = jax.random.normal(key, (B, Skv, H, hd))
    kv_pos = jnp.broadcast_to(jnp.arange(Skv), (B, Skv))
    q_pos = jnp.full((B, 1), 10)
    valid = kv_pos[0] <= 10
    out = attention_ref(q, k, v, q_pos=q_pos, kv_pos=kv_pos, causal=True)
    ref = attention_ref(q, k[:, :11], v[:, :11], causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ---------------------------------------------------------------------------
# pim_mvm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("M,K,N,bm,bn,bk", [
    (128, 256, 128, 128, 128, 128),
    (256, 512, 384, 128, 128, 256),
    (64, 128, 256, 64, 256, 128),
])
def test_pim_mvm_matches_ref(M, K, N, bm, bn, bk):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (M, K), jnp.float32)
    w = jax.random.normal(k2, (K, N), jnp.float32)
    wq, s = quantize_weights(w)
    out = pim_mvm(x, wq, s, impl="pallas_interpret", bm=bm, bn=bn, bk=bk)
    ref = pim_mvm_ref(x, wq, s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-3, rtol=1e-4)


def test_pim_mvm_bf16_activation():
    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(k1, (128, 256), jnp.bfloat16)
    w = jax.random.normal(k2, (256, 128), jnp.float32)
    wq, s = quantize_weights(w)
    out = pim_mvm(x, wq, s, impl="pallas_interpret")
    ref = pim_mvm_ref(x, wq, s)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=0.5, rtol=5e-2)


def test_quantization_fidelity():
    """Per-crossbar int8 quantisation keeps MVM error ≲1% — the property the
    ReRAM plane needs for the paper's static layers."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(2))
    x = jax.random.normal(k1, (64, 512), jnp.float32)
    w = jax.random.normal(k2, (512, 256), jnp.float32)
    wq, s = quantize_weights(w)
    exact = x @ w
    approx = pim_mvm_ref(x, wq, s)
    rel = float(jnp.abs(approx - exact).max() / jnp.abs(exact).max())
    assert rel < 0.02, rel


def test_quantization_roundtrip_monotone():
    """dequant(quant(w)) is within one quantisation step of w everywhere."""
    w = jax.random.normal(jax.random.PRNGKey(3), (256, 256), jnp.float32)
    wq, s = quantize_weights(w)
    back = dequantize_ref(wq, s)
    step = jnp.repeat(jnp.repeat(s, 128, 0), 128, 1)
    assert bool((jnp.abs(back - w) <= step * 0.5 + 1e-7).all())


def test_pim_mvm_rejects_bad_tiles():
    x = jnp.zeros((64, 100))
    wq = jnp.zeros((100, 128), jnp.int8)
    s = jnp.ones((1, 1))
    with pytest.raises((ValueError, AssertionError)):
        pim_mvm(x, wq, s, impl="pallas_interpret")
