"""Quantization plane: round-trip properties (scales, int4 pack/unpack,
error bounds), the fused dequant-matmul and quantised-KV decode kernels vs
their fp oracles, the quantised serving engine (token parity against the
fake-quant oracle), and the precision-aware Plane-B traffic model."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduce_config
from repro.quant.core import (QMAX, QuantTensor, dequantize, dequantize_kv,
                              fake_quantize_params, pack_int4, quantize,
                              quantize_kv, quantize_kv_cache, quantize_params,
                              unpack_int4)
from repro.quant.ops import quant_matmul


# ---------------------------------------------------------------------------
# round-trip properties
# ---------------------------------------------------------------------------

def test_int4_pack_unpack_bijective():
    """Every int4 code value survives pack→unpack on any axis."""
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.integers(-8, 8, size=(6, 10, 8)), jnp.int8)
    for axis in (-1, 0, 1):
        p = pack_int4(c, axis=axis)
        assert p.shape[axis] * 2 == c.shape[axis]
        assert (unpack_int4(p, axis=axis) == c).all()
    # the full nibble range, incl. the -8 edge
    edge = jnp.asarray([[-8, 7], [-1, 0], [3, -5]], jnp.int8)
    assert (unpack_int4(pack_int4(edge, -1), -1) == edge).all()


def test_pack_int4_odd_axis_raises():
    with pytest.raises(ValueError, match="even"):
        pack_int4(jnp.zeros((3, 5), jnp.int8), axis=-1)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("group", [0, 16])
def test_weight_quant_scale_correctness_and_error_bound(bits, group):
    """Per-channel/group scales equal max|w|/qmax over their group, and the
    reconstruction error is bounded by scale/2 (round-to-nearest)."""
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 48))
    qt = quantize(w, bits, group=group)
    qmax = QMAX[bits]
    wf = np.asarray(w, np.float64)
    if group:
        grp = wf.reshape(64 // group, group, 48)
        expect = np.abs(grp).max(axis=1) / qmax
    else:
        expect = np.abs(wf).max(axis=0, keepdims=True) / qmax
    np.testing.assert_allclose(np.asarray(qt.scale), expect, rtol=1e-6)
    err = np.abs(np.asarray(dequantize(qt)) - wf)
    scale_full = np.repeat(expect, group, axis=0) if group else expect
    assert (err <= scale_full / 2 + 1e-7).all()


def test_dequant_error_shrinks_with_bit_width():
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    err = {bits: float(jnp.abs(dequantize(quantize(w, bits)) - w).max())
           for bits in (8, 4)}
    assert err[8] < err[4]
    # int8 error ~ scale/2 = max|w|/254; int4 ~ max|w|/14
    mx = float(jnp.abs(w).max())
    assert err[8] <= mx / 254 * 1.01
    assert err[4] <= mx / 14 * 1.01


@pytest.mark.parametrize("bits", [8, 4])
def test_kv_quant_round_trip(bits):
    """Per-(token, head) scales: row-wise error bound; all-zero rows (empty
    slots) reconstruct exact zeros."""
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 7, 4, 16))
    x = x.at[0, 3].set(0.0)                      # an empty row per head
    codes, scale = quantize_kv(x, bits)
    assert codes.dtype == jnp.int8
    assert scale.shape == (2, 7, 4)
    back = dequantize_kv(codes, scale, bits)
    bound = np.asarray(scale)[..., None] / 2 + 1e-7
    assert (np.abs(np.asarray(back - x)) <= bound).all()
    assert (np.asarray(back[0, 3]) == 0.0).all()


def test_quantize_invalid_bits_raises():
    w = jnp.zeros((8, 8))
    with pytest.raises(ValueError):
        quantize(w, 16)
    with pytest.raises(ValueError):
        quantize_kv(w, 2)


# ---------------------------------------------------------------------------
# fused dequant-matmul kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("group", [0, 32])
def test_quant_matmul_kernel_matches_ref(bits, group):
    """The Pallas kernel (interpret mode) reproduces the reference
    dequant+matmul bit-for-bit (both accumulate the same dequantised f32
    weights)."""
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 128))
    w = jax.random.normal(jax.random.PRNGKey(4), (128, 256))
    qt = quantize(w, bits, group=group)
    ref = quant_matmul(x, qt, impl="ref")
    out = quant_matmul(x, qt, impl="pallas_interpret")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=1e-5)


def test_quant_matmul_untileable_falls_back():
    """Shapes the Pallas grid can't tile exactly fall back to ref."""
    x = jax.random.normal(jax.random.PRNGKey(5), (3, 48))
    w = jax.random.normal(jax.random.PRNGKey(6), (48, 50))
    qt = quantize(w, 8)
    out = quant_matmul(x, qt, impl="pallas_interpret")
    ref = quant_matmul(x, qt, impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref))


# ---------------------------------------------------------------------------
# quantised-KV decode kernel vs fp oracle
# ---------------------------------------------------------------------------

def _pool(key, B, Skv, Hq, Hkv, hd, lengths, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, hd), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, hd), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, hd), dtype)
    L = np.asarray(lengths, np.int32)
    kv_pos = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32), (B, Skv))
    kv_pos = jnp.where(kv_pos < L[:, None], kv_pos, -1)
    q_pos = jnp.asarray(L[:, None] - 1, jnp.int32)
    return q, k, v, q_pos, kv_pos


@pytest.mark.parametrize("Hq,Hkv", [(4, 4), (4, 2), (8, 1)])  # MHA/GQA/MQA
@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("bits", [8, 4])
def test_quant_decode_kernel_matches_dequant_oracle(Hq, Hkv, window, bits):
    """The quantised-KV decode kernel equals the reference attention over
    the *dequantised* cache (same codes, same scales) — quantisation error
    lives entirely in the representation, never in the kernel."""
    from repro.kernels.flash_attention.decode import flash_decode_quant_fwd
    from repro.kernels.flash_attention.ref import attention_ref

    B, Skv, hd = 3, 64, 32
    q, k, v, q_pos, kv_pos = _pool(jax.random.PRNGKey(0), B, Skv, Hq, Hkv,
                                   hd, lengths=[3, 31, 64])
    k_q, k_s = quantize_kv(k, bits)
    v_q, v_s = quantize_kv(v, bits)
    out = flash_decode_quant_fwd(q, k_q, k_s, v_q, v_s, kv_bits=bits,
                                 q_pos=q_pos, kv_pos=kv_pos, window=window,
                                 interpret=True)
    ref = attention_ref(q, dequantize_kv(k_q, k_s, bits).astype(q.dtype),
                        dequantize_kv(v_q, v_s, bits).astype(q.dtype),
                        q_pos=q_pos, kv_pos=kv_pos, kv_valid=kv_pos >= 0,
                        causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_quant_decode_kernel_empty_slot_zeros():
    from repro.kernels.flash_attention.decode import flash_decode_quant_fwd

    q, k, v, q_pos, kv_pos = _pool(jax.random.PRNGKey(1), 2, 32, 4, 2, 16,
                                   lengths=[10, 20])
    kv_pos = kv_pos.at[1].set(-1)
    k_q, k_s = quantize_kv(k, 8)
    v_q, v_s = quantize_kv(v, 8)
    out = flash_decode_quant_fwd(q, k_q, k_s, v_q, v_s, kv_bits=8,
                                 q_pos=q_pos, kv_pos=kv_pos, interpret=True)
    assert bool(jnp.isfinite(out).all())
    assert bool((out[1] == 0.0).all())


def test_ops_quant_route_matches_ref_route():
    """ops.attention with k_scale/v_scale: the kernel route and the
    dequantise-up-front ref route agree."""
    from repro.kernels.flash_attention.ops import attention

    q, k, v, q_pos, kv_pos = _pool(jax.random.PRNGKey(2), 2, 64, 4, 2, 16,
                                   lengths=[20, 64])
    k_q, k_s = quantize_kv(k, 4)
    v_q, v_s = quantize_kv(v, 4)
    kw = dict(k_scale=k_s, v_scale=v_s, kv_bits=4, q_pos=q_pos,
              kv_pos=kv_pos, kv_valid=kv_pos >= 0, causal=True)
    out = attention(q, k_q, v_q, impl="flash", **kw)
    ref = attention(q, k_q, v_q, impl="ref", **kw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# parameter-tree quantisation
# ---------------------------------------------------------------------------

def test_quantize_params_selects_dense_projections_only():
    from repro.models import transformer as T

    cfg = reduce_config(get_config("qwen3-moe-30b-a3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(params, 8)
    leaves = jax.tree_util.tree_leaves_with_path(
        qp, is_leaf=lambda x: isinstance(x, QuantTensor))

    def kinds(pred):
        return {str(getattr(p[-1], "key", "")) for p, l in leaves if pred(l)}

    quantised = kinds(lambda l: isinstance(l, QuantTensor))
    kept_fp = kinds(lambda l: not isinstance(l, QuantTensor))
    assert {"wq", "wk", "wv", "wo"} <= quantised
    # router, biases, norms, embeddings and the 4-D MoE expert banks stay fp
    assert "router" in kept_fp
    assert "tok" in kept_fp
    for pth, leaf in leaves:
        keys = [str(getattr(p, "key", "")) for p in pth]
        if "experts" in keys:
            assert not isinstance(leaf, QuantTensor), keys


def test_fake_quantize_params_matches_quantised_values():
    from repro.models import transformer as T

    cfg = reduce_config(get_config("qwen2.5-3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    qp = quantize_params(params, 8)
    fq = fake_quantize_params(params, 8)
    qt = qp["stack"][0]["u0"]["attn"]["wq"]
    assert isinstance(qt, QuantTensor)
    np.testing.assert_array_equal(
        np.asarray(dequantize(qt)),
        np.asarray(fq["stack"][0]["u0"]["attn"]["wq"]))


# ---------------------------------------------------------------------------
# serving engine: quantised paths
# ---------------------------------------------------------------------------

def _drain(cfg, params, *, weight_bits=0, kv_bits=0, impl="ref",
           prompts=(6, 10, 14), max_new=5, kv_len=64, max_batch=3):
    from repro.serving.engine import EngineConfig, ServingEngine

    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=max_batch, kv_len=kv_len, max_new_tokens=max_new,
        impl=impl, prefill_chunk=32, weight_bits=weight_bits,
        kv_bits=kv_bits))
    rng = np.random.default_rng(0)
    for plen in prompts:
        eng.submit(rng.integers(0, cfg.vocab_size, size=plen))
    done = eng.run_until_drained()
    return [tuple(r.output) for r in sorted(done, key=lambda r: r.uid)], eng


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "gemma2-9b"])
def test_engine_w8_matches_fake_quant_oracle_exactly(arch):
    """Weight-only int8 serving must be token-identical to an fp engine
    running the dequantise(quantise(W)) weights: the quantised path changes
    the weight *values* once, offline — never the arithmetic."""
    from repro.models import transformer as T

    cfg = reduce_config(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.float32)
    got, _ = _drain(cfg, params, weight_bits=8)
    oracle, _ = _drain(cfg, fake_quantize_params(params, 8))
    assert got == oracle


@pytest.mark.parametrize("arch,wb,kb", [
    ("qwen2.5-3b", 8, 8),        # GQA, packed admission
    ("gemma2-9b", 8, 8),         # local sliding-window ring + softcaps
    ("recurrentgemma-9b", 8, 8),  # hybrid local+recurrent (padded admission)
    ("qwen2.5-3b", 4, 4),        # packed-int4 extreme
])
def test_engine_quantised_drains_and_tracks_fp(arch, wb, kb):
    """Quantised serving drains every request to completion with the same
    episode shape as fp; int8 stays close to the fp tokens (bounded drift —
    random-init reduced models have tiny logit margins, so exact parity is
    not required here; the fake-quant oracle test pins exactness where it
    is well-defined)."""
    from repro.models import transformer as T

    cfg = reduce_config(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.float32)
    fp, _ = _drain(cfg, params)
    out, eng = _drain(cfg, params, weight_bits=wb, kv_bits=kb)
    assert len(out) == len(fp)
    assert [len(o) for o in out] == [len(f) for f in fp]
    if wb == 8:
        prefix = np.mean([sum(x == y for x, y in zip(a, b)) / max(len(a), 1)
                          for a, b in zip(fp, out)])
        assert prefix >= 0.4, f"int8 drifted too far from fp: {prefix}"
    stats = eng.stats()
    assert stats["weight_bits"] == (wb or 16)
    assert stats["kv_bits"] == (kb or 16)


def test_engine_kv_cache_stored_quantised():
    """kv_bits=8 keeps the slot pool int8 end-to-end: no fp k/v leaves
    exist in the engine cache, and the code/scale planes are populated by
    prefill + decode commits."""
    from repro.models import transformer as T

    cfg = reduce_config(get_config("qwen2.5-3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.float32)
    _, eng = _drain(cfg, params, kv_bits=8)
    leaves = jax.tree_util.tree_flatten_with_path(eng.cache)[0]
    names = {str(getattr(p[-1], "key", "")) for p, _ in leaves}
    assert {"k_q", "k_s", "v_q", "v_s"} <= names
    assert "k" not in names and "v" not in names
    for pth, leaf in leaves:
        name = str(getattr(pth[-1], "key", ""))
        if name in ("k_q", "v_q"):
            assert leaf.dtype == jnp.int8
            assert int(jnp.abs(leaf).max()) > 0    # commits actually landed


def test_engine_quant_flash_impl_matches_ref_impl_shape():
    """The quantised pool also routes through the Pallas decode kernel
    (impl='flash'); both impls drain with identical episode shapes."""
    from repro.models import transformer as T

    cfg = reduce_config(get_config("qwen2.5-3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.float32)
    ref, _ = _drain(cfg, params, weight_bits=8, kv_bits=8, impl="ref")
    fl, _ = _drain(cfg, params, weight_bits=8, kv_bits=8, impl="flash")
    assert [len(o) for o in fl] == [len(o) for o in ref]


def test_engine_invalid_bits_raise():
    from repro.models import transformer as T
    from repro.serving.engine import EngineConfig, ServingEngine

    cfg = reduce_config(get_config("qwen2.5-3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="weight_bits"):
        ServingEngine(cfg, params, EngineConfig(weight_bits=3))
    with pytest.raises(ValueError, match="kv_bits"):
        ServingEngine(cfg, params, EngineConfig(kv_bits=16))


# ---------------------------------------------------------------------------
# precision-aware Plane-B traffic + bridge
# ---------------------------------------------------------------------------

def test_traffic_precision_scaling_monotone():
    from repro.core.traffic import (Workload, decode_step_phases,
                                    decode_weight_stream_bytes,
                                    total_traffic_bytes)

    cfg = get_config("qwen2.5-3b")
    tot = {}
    for bits in (16, 8, 4):
        w = Workload.from_config(cfg, seq_len=128, weight_bits=bits,
                                 kv_bits=bits)
        tot[bits] = total_traffic_bytes(decode_step_phases(w, 200, 4))
    assert tot[4] < tot[8] < tot[16]
    # weight streams halve (plus the small f32 scale plane) at int8
    w16 = Workload.from_config(cfg, seq_len=128)
    w8 = Workload.from_config(cfg, seq_len=128, weight_bits=8)
    ratio = decode_weight_stream_bytes(w8) / decode_weight_stream_bytes(w16)
    assert 0.5 < ratio < 0.52


def test_traffic_fp16_default_unchanged():
    """weight_bits=kv_bits=16 is the pre-quantisation model, term by term
    (the Table-4 calibration surface cannot move)."""
    from repro.core import traffic

    w_def = traffic.Workload.from_config(get_config("gpt-j"), seq_len=64)
    w_exp = traffic.Workload.from_config(get_config("gpt-j"), seq_len=64,
                                         weight_bits=16, kv_bits=16)
    for fn in (traffic.transformer_phases, traffic.prefill_phases):
        for a, b in zip(fn(w_def), fn(w_exp)):
            assert dataclasses.asdict(a) == dataclasses.asdict(b)
    assert w_def.weight_dram_bytes(100, 200) == 100 * 200 * traffic.BYTES


def test_traffic_invalid_bits_raise():
    from repro.core.traffic import Workload

    with pytest.raises(ValueError, match="precision"):
        Workload.from_config(get_config("gpt-j"), seq_len=8, weight_bits=2)


def test_kv_cache_bytes_scale_with_kv_bits():
    from repro.core.traffic import Workload, kv_cache_bytes_per_layer

    cfg = get_config("qwen2.5-3b")
    w16 = Workload.from_config(cfg, seq_len=64)
    w8 = Workload.from_config(cfg, seq_len=64, kv_bits=8)
    w4 = Workload.from_config(cfg, seq_len=64, kv_bits=4)
    b16 = kv_cache_bytes_per_layer(w16, 1000)
    b8 = kv_cache_bytes_per_layer(w8, 1000)
    b4 = kv_cache_bytes_per_layer(w4, 1000)
    assert b4 < b8 < b16
    # int8 halves the element bytes; the f32 per-(token, head) scale plane
    # rides on top
    assert b8 == pytest.approx(b16 / 2 + 2.0 * 1000 * w8.n_kv_heads * 4)


def test_bridge_carries_measured_precision():
    """engine(weight_bits=8, kv_bits=8) → stats → mix_from_stats →
    cosim_from_engine: the replayed Plane-B traffic shrinks vs the fp
    replay of the same mix."""
    import dataclasses as dc

    from repro.core.cosim import cosim_mix, mix_from_stats
    from repro.models import transformer as T

    cfg = reduce_config(get_config("qwen2.5-3b"))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.float32)
    _, eng = _drain(cfg, params, weight_bits=8, kv_bits=8)
    mix = mix_from_stats(eng.stats())
    assert mix.weight_bits == 8 and mix.kv_bits == 8
    full = get_config("qwen2.5-3b")
    quant = cosim_mix(full, mix, 64)
    fp = cosim_mix(full, dc.replace(mix, weight_bits=16, kv_bits=16), 64)
    for arch in quant:
        assert quant[arch]["decode_bytes"] < fp[arch]["decode_bytes"]
        assert quant[arch]["prefill_bytes"] < fp[arch]["prefill_bytes"]


def test_generation_phases_scale_with_precision():
    from repro.core.cosim import Episode, EpisodeMix, generation_phases
    from repro.core.traffic import total_traffic_bytes

    def mix(bits):
        return EpisodeMix([Episode(64, 16, 2)], prefill_chunk=16,
                          max_batch=4, active_hist={4: 1},
                          max_stall_tokens=16,
                          weight_bits=bits, kv_bits=bits)

    t16 = total_traffic_bytes(generation_phases("qwen2.5-3b", mix(16)))
    t8 = total_traffic_bytes(generation_phases("qwen2.5-3b", mix(8)))
    assert t8 < 0.7 * t16


# ---------------------------------------------------------------------------
# report hardening (malformed BENCH_*.json must not kill the report)
# ---------------------------------------------------------------------------

def test_report_skips_malformed_records(tmp_path, monkeypatch, capsys):
    import benchmarks.report as report

    dryrun = tmp_path / "dryrun"
    dryrun.mkdir()
    (dryrun / "broken.json").write_text('{"arch": "x", "shape":')  # truncated
    (dryrun / "nokeys.json").write_text('{"unrelated": 1}')
    (dryrun / "ok.json").write_text(
        '{"arch": "a", "shape": "s", "mesh": "single", "status": "skipped",'
        ' "reason": "test"}')
    monkeypatch.setattr(report, "DRYRUN", str(dryrun))

    recs = report.load()
    assert list(recs) == [("a", "s", "single")]
    err = capsys.readouterr().err
    assert "broken.json" in err and "nokeys.json" in err

    # malformed benchmark records degrade to a notice, not a traceback
    (tmp_path / "BENCH_serving.json").write_text("{not json")
    (tmp_path / "BENCH_cosim.json").write_text('["wrong shape"')
    (tmp_path / "BENCH_quant.json").write_text("")
    assert "malformed" in report.serving_table()
    assert "malformed" in report.cosim_table()
    assert "malformed" in report.quant_table()

    # valid JSON with a stale schema (missing keys) degrades per-section
    (tmp_path / "BENCH_quant.json").write_text('{"arch": "x"}')
    assert "section unavailable" in report._render(report.quant_table)


def test_report_quant_table_renders(tmp_path, monkeypatch):
    """quant_table renders the real smoke record when present."""
    import json
    import os

    import benchmarks.report as report

    smoke = os.path.join(os.path.dirname(report.__file__), "..",
                         "experiments", "BENCH_quant_smoke.json")
    if not os.path.exists(smoke):
        pytest.skip("no quant smoke record")
    dryrun = tmp_path / "dryrun"
    dryrun.mkdir()
    rec = json.load(open(smoke))
    (tmp_path / "BENCH_quant.json").write_text(json.dumps(rec))
    monkeypatch.setattr(report, "DRYRUN", str(dryrun))
    table = report.quant_table()
    assert "fake-quant oracle parity" in table
    assert "Plane-B projection" in table
