PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test verify bench-serving bench-smoke report

test:               ## tier-1 test suite
	$(PY) -m pytest -x -q

bench-serving:      ## full serving decode+prefill benchmark -> experiments/BENCH_serving.json
	$(PY) -m benchmarks.perf_serving

bench-smoke:        ## tiny-config serving benchmark; asserts the JSON report schema
	$(PY) -m benchmarks.perf_serving --smoke

verify:             ## CI gate: tier-1 tests + serving bench smoke (schema-checked)
	$(PY) -m pytest -x -q
	$(MAKE) bench-smoke

report:             ## render benchmark/dry-run tables
	$(PY) -m benchmarks.report
