"""Resilience benchmark: graceful degradation under fabric faults and
engine overload.

Three sections:

- **zoo_faults** — every zoo model's generation episode re-simulated on
  2.5D-HI under k ∈ {0, 1, 2} failed NoI links (k=1 exhaustive, k=2 a
  deterministic capped enumeration): mean/worst TTFT and decode-step
  inflation over the surviving scenarios plus the count of scenarios the
  fabric could not route at all (``DisconnectedFabric``).
- **noi_fault_search** — the tentpole comparison: for each model, the NoI
  design MOO-STAGE finds under the *fault-oblivious* generation objective
  vs the *fault-aware* one (``core.cosim.resilience_objective``: expected
  + worst-case μ over a seeded k-failure scenario set, disconnection
  inadmissible).  Both designs are then scored under the same exhaustive
  k=1 (and capped k=2) failure sweeps — the fault-aware design should
  carry a lower worst-case degradation and never disconnect at k=1.
- **engine_overload** — Plane A goodput under a burst far over capacity
  with tight per-request deadlines, with and without bounded-queue
  shedding (``EngineConfig(max_queue=)``): shedding turns queue-rot
  (admitted too late, evicted mid-decode, compute wasted) into instant
  retriable REJECTs, sustaining higher goodput from the same slot pool.

    PYTHONPATH=src python -m benchmarks.perf_resilience [--smoke]

Results: ``experiments/BENCH_resilience.json``
(``BENCH_resilience_smoke.json`` with ``--smoke``); rendered by
``benchmarks/report.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import time

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "experiments")

ZOO = ("llama2-7b", "gpt-j", "gemma2-9b", "qwen2.5-3b",
       "bart-large", "whisper-large-v3")

_ZOO_KEYS = {"model", "k", "n_scenarios", "n_disconnected", "ttft_ms_mean",
             "ttft_ms_worst", "decode_step_ms_mean", "decode_step_ms_worst",
             "ttft_inflation_worst", "decode_inflation_worst"}

_SEARCH_KEYS = {"model", "chiplets", "oblivious", "aware", "gain_worst_k1",
                "aware_survives_k1", "same_design", "n_evals"}

_SCORE_KEYS = {"nominal_t", "worst_t_k1", "degradation_k1",
               "n_disconnected_k1", "degradation_k2", "n_disconnected_k2",
               "links"}

_OVERLOAD_KEYS = {"policy", "submitted", "done", "rejected",
                  "failed_deadline", "goodput_tok_s", "wall_s",
                  "done_tokens"}


def check_schema(rec: dict) -> None:
    """Assert the BENCH_resilience.json record shape (CI bit-rot gate)."""
    for key in ("bench", "smoke", "chiplets", "prompt_len", "gen_len",
                "batch", "zoo_faults", "noi_fault_search",
                "engine_overload"):
        assert key in rec, f"missing top-level key {key!r}"
    zf = rec["zoo_faults"]["cells"]
    assert zf, "zoo_faults must not be empty"
    ks = set()
    for cell in zf:
        missing = _ZOO_KEYS - set(cell)
        assert not missing, f"zoo_faults cell missing {missing}"
        ks.add(cell["k"])
    assert {0, 1, 2} <= ks, f"zoo_faults must sweep k in {{0,1,2}}: {ks}"
    cells = rec["noi_fault_search"]["cells"]
    assert cells, "noi_fault_search must not be empty"
    for cell in cells:
        missing = _SEARCH_KEYS - set(cell)
        assert not missing, f"noi_fault_search cell missing {missing}"
        for side in ("oblivious", "aware"):
            smissing = _SCORE_KEYS - set(cell[side])
            assert not smissing, f"{side} score missing {smissing}"
    if not rec["smoke"]:
        assert len(cells) >= 3, "full sweep must cover >=3 models"
        improved = [c for c in cells
                    if c["gain_worst_k1"] is None or c["gain_worst_k1"] > 1.0]
        assert len(improved) >= 3, (
            "fault-aware search must reduce worst-case k=1 degradation "
            f"on >=3 models (got {len(improved)})")
    ov = rec["engine_overload"]["rows"]
    assert {r["policy"] for r in ov} == {"no_shed", "shed"}
    for row in ov:
        missing = _OVERLOAD_KEYS - set(row)
        assert not missing, f"engine_overload row missing {missing}"
    if not rec["smoke"]:
        by = {r["policy"]: r for r in ov}
        assert by["shed"]["goodput_tok_s"] >= by["no_shed"]["goodput_tok_s"], \
            "shedding must sustain >= goodput under overload"


# ---------------------------------------------------------------------------
# zoo sweep: generation latency under k link failures
# ---------------------------------------------------------------------------

def run_zoo_faults(models, chiplets: int, prompt_len: int, gen_len: int,
                   batch: int, *, max_scenarios: int = 24) -> dict:
    from repro.config import get_config
    from repro.core.faults import DisconnectedFabric, all_link_scenarios
    from repro.core.placement import initial_placement
    from repro.core.simulator import simulate_generation
    from repro.core.traffic import Workload

    p = initial_placement(chiplets)
    sweeps = {0: [None],
              1: all_link_scenarios(p, k=1, max_scenarios=max_scenarios),
              2: all_link_scenarios(p, k=2, max_scenarios=max_scenarios)}
    cells = []
    for name in models:
        w = Workload.from_config(get_config(name), seq_len=prompt_len)
        nominal = None
        for k, scenarios in sweeps.items():
            ttfts, steps, n_disc = [], [], 0
            for sc in scenarios:
                try:
                    g = simulate_generation(w, chiplets, prompt_len,
                                            gen_len, arch="2.5D-HI",
                                            placement=p, batch=batch,
                                            scenario=sc)
                except DisconnectedFabric:
                    n_disc += 1
                    continue
                ttfts.append(g.ttft_s * 1e3)
                steps.append(g.decode_step_s * 1e3)
            if k == 0:
                nominal = (ttfts[0], steps[0])
            cells.append({
                "model": name, "k": k,
                "n_scenarios": len(scenarios),
                "n_disconnected": n_disc,
                "ttft_ms_mean": sum(ttfts) / len(ttfts) if ttfts else None,
                "ttft_ms_worst": max(ttfts) if ttfts else None,
                "decode_step_ms_mean":
                    sum(steps) / len(steps) if steps else None,
                "decode_step_ms_worst": max(steps) if steps else None,
                "ttft_inflation_worst":
                    max(ttfts) / nominal[0] if ttfts else None,
                "decode_inflation_worst":
                    max(steps) / nominal[1] if steps else None,
            })
    return {"chiplets": chiplets, "max_scenarios": max_scenarios,
            "cells": cells}


# ---------------------------------------------------------------------------
# NoI search: fault-oblivious vs fault-aware designs under failure sweeps
# ---------------------------------------------------------------------------

def _score_under_faults(design, phases, *, k2_cap: int) -> dict:
    """Fabric-service-time degradation of one placement under exhaustive
    k=1 and capped k=2 link-failure sweeps.  Disconnection is reported as
    a flag + count (JSON-safe), never an inf latency."""
    from repro.core.cosim import degradation_under_faults, fabric_time
    from repro.core.faults import all_link_scenarios

    out = {"links": len(design.links),
           "nominal_t": fabric_time(design, phases)}
    for k, cap in ((1, 0), (2, k2_cap)):
        rep = degradation_under_faults(
            design, phases, all_link_scenarios(design, k=k,
                                               max_scenarios=cap))
        disc = rep["n_disconnected"]
        if k == 1:
            out["worst_t_k1"] = None if disc else rep["worst_t"]
        out[f"degradation_k{k}"] = (None if disc else
                                    rep["worst_t"]
                                    / max(out["nominal_t"], 1e-30))
        out[f"n_disconnected_k{k}"] = disc
    return out


def run_noi_fault_search(models, chiplets: int, prompt_len: int,
                         gen_len: int, *, batch: int = 8, requests: int = 4,
                         iterations: int = 3, ls_steps: int = 12,
                         n_scenarios: int = 8, k2_cap: int = 40,
                         seed: int = 0) -> dict:
    import numpy as np

    from repro.core.cosim import (Episode, EpisodeMix, fabric_time,
                                  generation_objective,
                                  resilience_objective, seeded_noi_search)
    from repro.core.faults import FaultModel

    chunk = max(prompt_len // 4, 1)
    cells = []
    for name in models:
        mix = EpisodeMix([Episode(prompt_len, gen_len, requests)],
                         prefill_chunk=chunk, max_batch=batch,
                         active_hist={batch: 1}, max_stall_tokens=chunk)
        # fault-oblivious designer: paper objective, then picks the design
        # with the best *nominal* fabric service time — never looks at
        # what a failure does to it
        obl_obj, _, phases = generation_objective(name, mix, chiplets)
        obl = seeded_noi_search(obl_obj, chiplets, iterations=iterations,
                                ls_steps=ls_steps, seed=seed)
        obl_design = min(obl.archive.designs,
                         key=lambda d: fabric_time(d, phases))

        # fault-aware designer: minimises worst-case service time over the
        # seeded k-failure set, picks the design with the best worst case.
        # Wear-weighted sampling (endurance_weighted) draws hot links —
        # the ones whose failure actually moves the bottleneck — so the
        # sampled worst case tracks the exhaustive one
        aw_obj, _, _ = resilience_objective(
            name, mix, chiplets, fault_model=FaultModel(k_links=1,
                                                        seed=seed),
            n_scenarios=n_scenarios, endurance_weighted=True)
        aw = seeded_noi_search(aw_obj, chiplets, iterations=iterations,
                               ls_steps=ls_steps, seed=seed)
        aobjs = np.asarray(aw.archive.objs)
        aw_design = aw.archive.designs[int(np.argmin(aobjs[:, 1]))]

        obl_score = _score_under_faults(obl_design, phases, k2_cap=k2_cap)
        aw_score = _score_under_faults(aw_design, phases, k2_cap=k2_cap)
        # worst-case k=1 service-time ratio oblivious/aware: > 1 means the
        # fault-aware design ends up *faster* under its worst single-link
        # failure; None = the oblivious design disconnects at k=1 while
        # the aware one survives (infinite gain)
        gain = None
        if obl_score["worst_t_k1"] is not None \
                and aw_score["worst_t_k1"] is not None:
            gain = obl_score["worst_t_k1"] / aw_score["worst_t_k1"]
        elif aw_score["worst_t_k1"] is None:
            gain = 0.0                # aware design itself disconnects
        cells.append({
            "model": name, "chiplets": chiplets,
            "oblivious": obl_score, "aware": aw_score,
            "gain_worst_k1": gain,
            "aware_survives_k1": aw_score["n_disconnected_k1"] == 0,
            "same_design": obl_design == aw_design,
            "n_evals": obl.n_evals + aw.n_evals,
        })
    return {"chiplets": chiplets, "batch": batch, "requests": requests,
            "iterations": iterations, "ls_steps": ls_steps,
            "n_scenarios": n_scenarios, "k2_cap": k2_cap, "seed": seed,
            "cells": cells}


# ---------------------------------------------------------------------------
# engine overload: goodput with vs without bounded-queue shedding
# ---------------------------------------------------------------------------

def run_engine_overload(*, arch: str = "qwen2.5-3b", burst: int = 12,
                        max_batch: int = 2, max_new_tokens: int = 16,
                        deadline_ms: float = 0.0,
                        max_queue: int = 2) -> dict:
    """Drain one over-capacity burst twice: unbounded queue (late
    admissions rot past their deadline mid-decode, wasting slot time) vs
    bounded-queue shedding (excess load fails fast as retriable REJECTED).
    Goodput counts only tokens of requests that finished DONE."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import get_config, reduce_config
    from repro.models import transformer as T
    from repro.serving.engine import DONE, EngineConfig, ServingEngine

    cfg = reduce_config(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.bfloat16)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=8)
               for _ in range(burst)]

    def drain(max_queue_, deadline_ms_):
        from repro.serving.engine import EngineStallError
        eng = ServingEngine(cfg, params, EngineConfig(
            max_batch=max_batch, kv_len=64, max_new_tokens=max_new_tokens,
            deadline_ms=deadline_ms_, max_queue=max_queue_))
        # warm the compiled prefill/decode paths so the timed burst
        # measures steady-state service, not XLA compilation (the warmup
        # request may itself miss a tight deadline mid-compile — fine)
        eng.submit(prompts[0].copy())
        eng.run_until_drained()
        t0 = time.perf_counter()
        reqs = [eng.submit(p.copy()) for p in prompts]
        try:
            eng.run_until_drained()
        except EngineStallError:
            pass                       # stranded requests are terminal too
        wall = time.perf_counter() - t0
        done_tokens = sum(len(r.output) for r in reqs if r.status == DONE)
        assert all(r.terminal for r in reqs)
        return {
            "policy": "shed" if max_queue_ else "no_shed",
            "submitted": burst,
            "done": sum(1 for r in reqs if r.status == DONE),
            "rejected": sum(1 for r in reqs if r.status == "rejected"),
            "failed_deadline": sum(1 for r in reqs
                                   if r.status == "failed_deadline"),
            "done_tokens": done_tokens,
            "wall_s": wall,
            "goodput_tok_s": done_tokens / max(wall, 1e-9),
        }

    # calibrate the deadline to the measured warm per-request service time
    # so the benchmark stresses the queue, not the host machine: the
    # deadline admits roughly what the slot pool + bounded queue can serve
    if deadline_ms <= 0.0:
        warm = drain(0, 0.0)
        per_req = warm["wall_s"] / burst * 1e3
        deadline_ms = per_req * (max_batch + max_queue) * 1.25
    rows = [drain(0, deadline_ms), drain(max_queue, deadline_ms)]
    return {"arch": arch, "burst": burst, "max_batch": max_batch,
            "max_new_tokens": max_new_tokens, "deadline_ms": deadline_ms,
            "max_queue": max_queue, "backend": jax.default_backend(),
            "rows": rows}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (seconds, still writes JSON)")
    ap.add_argument("--chiplets", type=int, default=36,
                    choices=(36, 64, 100))
    ap.add_argument("--prompt-len", type=int, default=512)
    ap.add_argument("--gen-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        args.out = os.path.join(
            EXPERIMENTS, "BENCH_resilience_smoke.json" if args.smoke
            else "BENCH_resilience.json")

    models = ("gemma2-9b", "bart-large") if args.smoke else ZOO
    if args.smoke:
        args.prompt_len, args.gen_len, args.batch = 64, 16, 4

    from benchmarks.common import emit

    rec = {
        "bench": "perf_resilience",
        "smoke": args.smoke,
        "chiplets": args.chiplets,
        "prompt_len": args.prompt_len,
        "gen_len": args.gen_len,
        "batch": args.batch,
        "zoo_faults": run_zoo_faults(
            models, args.chiplets, args.prompt_len, args.gen_len,
            args.batch, max_scenarios=6 if args.smoke else 64),
        "noi_fault_search": run_noi_fault_search(
            models, args.chiplets, args.prompt_len, args.gen_len,
            batch=args.batch,
            iterations=1 if args.smoke else 3,
            ls_steps=4 if args.smoke else 12,
            n_scenarios=4 if args.smoke else 16,
            k2_cap=10 if args.smoke else 40),
        "engine_overload": run_engine_overload(
            burst=6 if args.smoke else 12,
            max_new_tokens=8 if args.smoke else 16),
    }
    check_schema(rec)

    emit([{"model": c["model"], "k": c["k"],
           "scenarios": c["n_scenarios"],
           "disconnected": c["n_disconnected"],
           "ttft_worst_ms": c["ttft_ms_worst"] or "",
           "decode_worst_ms": c["decode_step_ms_worst"] or "",
           "decode_inflation": c["decode_inflation_worst"] or ""}
          for c in rec["zoo_faults"]["cells"]],
         f"resilience: generation under k link failures "
         f"({args.chiplets} chiplets)")
    emit([{"model": c["model"],
           "obl_deg_k1": c["oblivious"]["degradation_k1"] or "disc",
           "obl_disc_k1": c["oblivious"]["n_disconnected_k1"],
           "aware_deg_k1": c["aware"]["degradation_k1"] or "disc",
           "aware_disc_k1": c["aware"]["n_disconnected_k1"],
           "gain_worst_k1": "inf" if c["gain_worst_k1"] is None
                            else c["gain_worst_k1"]}
          for c in rec["noi_fault_search"]["cells"]],
         "resilience: fault-oblivious vs fault-aware NoI designs (k=1)")
    emit(rec["engine_overload"]["rows"],
         "resilience: engine overload goodput (shed vs no-shed)")

    os.makedirs(EXPERIMENTS, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"# wrote {os.path.normpath(args.out)}")


if __name__ == "__main__":
    main()
