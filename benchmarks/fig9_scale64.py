"""Fig. 9: end-to-end latency + energy, 64-chiplet system, BERT-Large and
BART-Large over sequence lengths.  Validates gain-grows-with-N."""
from repro.config import get_config
from repro.core.baselines import simulate_haima_chiplet, simulate_transpim_chiplet
from repro.core.simulator import simulate_2p5d_hi
from repro.core.traffic import Workload

from benchmarks.common import emit


def run(verbose: bool = True) -> list[dict]:
    rows = []
    for arch in ("bert-large", "bart-large"):
        for n in (64, 256, 1024, 4096):
            w = Workload.from_config(get_config(arch), seq_len=n)
            hi = simulate_2p5d_hi(w, 64)
            ha = simulate_haima_chiplet(w, 64)
            tp = simulate_transpim_chiplet(w, 64)
            rows.append({
                "arch": arch, "seq_len": n,
                "hi_ms": hi.latency_s * 1e3,
                "haima_gain_x": ha.latency_s / hi.latency_s,
                "transpim_gain_x": tp.latency_s / hi.latency_s,
                "haima_egain_x": ha.energy_j / hi.energy_j,
                "transpim_egain_x": tp.energy_j / hi.energy_j,
            })
    if verbose:
        emit(rows, "fig9: 64-chiplet scaling (BERT-Large / BART-Large)")
    for arch in ("bert-large", "bart-large"):
        sub = [r for r in rows if r["arch"] == arch]
        assert sub[-1]["transpim_gain_x"] > sub[0]["transpim_gain_x"], \
            "gain must grow with N (paper: 4.6x -> 5.45x)"
        assert all(r["haima_gain_x"] > 1 and r["transpim_gain_x"] > 1
                   for r in sub)
    return rows


if __name__ == "__main__":
    run()
