"""Gemma-2-9B — alternating local/global attention + logit softcaps.
[arXiv:2408.00118; hf]"""
from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    head_dim=256,
    d_ff=14_336,
    vocab_size=256_000,
    pattern=("local", "global"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    post_norm=True,
    rope_theta=10_000.0,
    act="gelu",
    glu=True,
    embed_scale=True,
    tie_embeddings=True,
    source="arXiv:2408.00118",
))
