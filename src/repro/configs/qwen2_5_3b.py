"""Qwen2.5-3B — dense GQA with QKV bias. [hf:Qwen/Qwen2.5-*; hf]"""
from repro.config import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=11_008,
    vocab_size=151_936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    act="silu",
    glu=True,
    tie_embeddings=True,
    source="hf:Qwen/Qwen2.5-3B",
))
