"""Pallas TPU kernels for the paper's two compute hot-spots.

- ``flash_attention``: the paper's SM-chiplet dataflow (FlashAttention
  partitioning with fused score+softmax, §3.1-3.2 steps 2-4) as a VMEM-tiled
  online-softmax kernel.
- ``pim_mvm``: the ReRAM-crossbar weight-stationary MVM (§3.1 step 5) as a
  quantised 128x128-tile matmul with in-kernel dequantisation.

Each kernel ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd wrapper with impl dispatch) and ``ref.py`` (pure-jnp oracle used for
interpret-mode validation and as the CPU/dry-run execution path).
"""
