"""Decode-aware co-simulation benchmark: serving-latency evaluation of the
chiplet architectures over the model zoo.

For each model the full generation episode (prompt prefill + KV-cache
write-back + autoregressive decode) runs through ``simulate_generation``
on 2.5D-HI, HAIMA_chiplet and TransPIM_chiplet, reporting TTFT, per-token
decode latency, steady-state decode tok/s, energy per generated token and
the prefill-vs-decode traffic split (decode dominates: weights re-stream
per token and the KV cache is read at every step).

Two optional sections (full run only):

- **bridge** — a real ``ServingEngine`` drain on a reduced config; its
  measured episode mix (``stats()`` → ``core.cosim.mix_from_stats``) is
  projected onto the full-size model and replayed through Plane B;
- **noi** — MOO-STAGE NoI design search over the *generation* traffic
  (``core.cosim.generation_objective``), vs the placement-unaware mesh.

    PYTHONPATH=src python -m benchmarks.perf_cosim [--smoke]

Results: ``experiments/BENCH_cosim.json`` (``BENCH_cosim_smoke.json`` with
``--smoke`` so CI never clobbers the recorded full run); rendered by
``benchmarks/report.py``.
"""
from __future__ import annotations

import argparse
import json
import os

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "experiments")

ARCHS = ("2.5D-HI", "HAIMA_chiplet", "TransPIM_chiplet")

# model zoo sweep: paper workloads + assigned archs covering MHA, GQA/MQA,
# parallel-block and encoder-decoder stacks
ZOO = ("llama2-7b", "gpt-j", "gemma2-9b", "qwen2.5-3b",
       "bart-large", "whisper-large-v3")

_ARCH_KEYS = {"ttft_ms", "decode_step_ms", "decode_tok_s", "tokens_per_s",
              "energy_per_token_mj", "prefill_gb", "decode_gb",
              "decode_traffic_frac"}


def check_schema(rec: dict) -> None:
    """Assert the BENCH_cosim.json record shape (CI bit-rot gate)."""
    for key in ("bench", "smoke", "chiplets", "prompt_len", "gen_len",
                "models"):
        assert key in rec, f"missing top-level key {key!r}"
    assert len(rec["models"]) >= 4 or rec["smoke"], "zoo must cover ≥4 models"
    saw_gqa = saw_encdec = False
    for name, row in rec["models"].items():
        saw_gqa |= row["kv_frac"] < 1.0
        saw_encdec |= row["enc_dec"]
        for arch in ARCHS:
            missing = _ARCH_KEYS - set(row["archs"][arch])
            assert not missing, f"{name}/{arch} missing {missing}"
    if not rec["smoke"]:
        assert saw_gqa and saw_encdec, "zoo must include GQA and enc-dec"


def _row(g) -> dict:
    return {
        "ttft_ms": g.ttft_s * 1e3,
        "decode_step_ms": g.decode_step_s * 1e3,
        "decode_tok_s": g.decode_tok_s,
        "tokens_per_s": g.tokens_per_s,
        "energy_per_token_mj": g.energy_per_token_j * 1e3,
        "prefill_gb": g.prefill_bytes / 2**30,
        "decode_gb": g.decode_bytes / 2**30,
        "decode_traffic_frac": g.decode_bytes
                               / max(g.prefill_bytes + g.decode_bytes, 1e-30),
    }


def run_zoo(models, chiplets: int, prompt_len: int, gen_len: int) -> dict:
    from repro.config import get_config
    from repro.core.simulator import simulate_generation
    from repro.core.traffic import Workload

    out = {}
    for name in models:
        cfg = get_config(name)
        w = Workload.from_config(cfg, seq_len=prompt_len)
        archs = {a: _row(simulate_generation(w, chiplets, prompt_len, gen_len,
                                             arch=a))
                 for a in ARCHS}
        hi = archs["2.5D-HI"]
        base_ttft = min(archs[a]["ttft_ms"] for a in ARCHS[1:])
        base_step = min(archs[a]["decode_step_ms"] for a in ARCHS[1:])
        base_epr = min(archs[a]["energy_per_token_mj"] for a in ARCHS[1:])
        out[name] = {
            "family": cfg.family,
            "kv_frac": w.kv_frac,
            "enc_dec": w.enc_dec,
            "archs": archs,
            "ttft_gain": base_ttft / hi["ttft_ms"],
            "decode_gain": base_step / hi["decode_step_ms"],
            "energy_gain": base_epr / hi["energy_per_token_mj"],
        }
    return out


def run_bridge(arch: str, chiplets: int) -> dict:
    """Measured-engine bridge: drain a small mixed workload on the reduced
    config, project the measured episode mix onto the full model."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.config import get_config, reduce_config
    from repro.core.cosim import cosim_from_engine
    from repro.models import transformer as T
    from repro.serving.engine import EngineConfig, ServingEngine

    cfg = reduce_config(get_config(arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0), param_dtype=jnp.bfloat16)
    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=4, kv_len=64, max_new_tokens=8, prefill_chunk=32))
    rng = np.random.default_rng(0)
    for plen in (6, 10, 14, 10, 22, 6):
        eng.submit(rng.integers(0, cfg.vocab_size, size=plen))
    eng.run_until_drained()
    rec = cosim_from_engine(eng, cfg=get_config(arch), n_chiplets=chiplets)
    rec["arch"] = arch
    rec["backend"] = jax.default_backend()
    return rec


def run_noi(arch: str, chiplets: int, prompt_len: int, gen_len: int,
            requests: int, seed: int = 0) -> dict:
    """Decode-aware NoI search: does a placement optimised under the
    generation traffic beat the placement-unaware mesh?"""
    import numpy as np

    from repro.core.cosim import (Episode, EpisodeMix, generation_objective,
                                  optimize_generation_noi)
    from repro.core.placement import initial_placement

    mix = EpisodeMix([Episode(prompt_len, gen_len, requests)])
    res, mesh_ev = optimize_generation_noi(arch, mix, chiplets,
                                           iterations=2, ls_steps=10,
                                           seed=seed)
    objective, _, _ = generation_objective(arch, mix, chiplets,
                                           mesh_ev=mesh_ev)
    front = np.asarray(res.archive.objs)
    # report one real design from the front (the min-μ point), not the
    # per-column minima of two different placements
    best = front[int(np.argmin(front[:, 0]))]
    seed_obj = objective(initial_placement(chiplets))
    return {
        "arch": arch, "chiplets": chiplets,
        "n_evals": res.n_evals,
        "pareto_points": len(res.archive.objs),
        "best_mu_norm": float(best[0]),
        "best_sigma_norm": float(best[1]),
        "seed_mu_norm": float(seed_obj[0]),
        "seed_sigma_norm": float(seed_obj[1]),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (seconds, still writes JSON)")
    ap.add_argument("--chiplets", type=int, default=64, choices=(36, 64, 100))
    ap.add_argument("--prompt-len", type=int, default=512)
    ap.add_argument("--gen-len", type=int, default=128)
    ap.add_argument("--bridge-arch", default="qwen2.5-3b")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        args.out = os.path.join(
            EXPERIMENTS,
            "BENCH_cosim_smoke.json" if args.smoke else "BENCH_cosim.json")

    models = ("gemma2-9b", "bart-large") if args.smoke else ZOO
    if args.smoke:
        args.prompt_len, args.gen_len = 64, 16

    from benchmarks.common import emit

    rec = {
        "bench": "perf_cosim",
        "smoke": args.smoke,
        "chiplets": args.chiplets,
        "prompt_len": args.prompt_len,
        "gen_len": args.gen_len,
        "models": run_zoo(models, args.chiplets, args.prompt_len,
                          args.gen_len),
    }
    if not args.smoke:
        rec["bridge"] = run_bridge(args.bridge_arch, args.chiplets)
        rec["noi"] = run_noi("qwen2.5-3b", 36, args.prompt_len, args.gen_len,
                             requests=4)
    check_schema(rec)

    rows = []
    for name, m in rec["models"].items():
        for arch in ARCHS:
            r = m["archs"][arch]
            rows.append({"model": name, "system": arch,
                         "ttft_ms": r["ttft_ms"],
                         "decode_ms_per_tok": r["decode_step_ms"],
                         "decode_tok_s": r["decode_tok_s"],
                         "energy_mj_per_tok": r["energy_per_token_mj"],
                         "decode_traffic_frac": r["decode_traffic_frac"]})
    emit(rows, f"cosim: generation episodes ({args.chiplets} chiplets, "
               f"prompt={args.prompt_len}, gen={args.gen_len})")
    if "noi" in rec:
        emit([rec["noi"]], "cosim: decode-aware NoI search (vs 2-D mesh)")

    os.makedirs(EXPERIMENTS, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"# wrote {os.path.normpath(args.out)}")


if __name__ == "__main__":
    main()
