"""Pallas TPU flash-attention forward kernel.

TPU-native adaptation of the paper's SM-chiplet attention dataflow: the
paper partitions Q/K/V across SM chiplets with the FlashAttention schedule
and fuses score+softmax so the O(N²) intermediate never crosses the NoI
(§3.2 steps 2-4).  On TPU the analogous fast/slow boundary is VMEM↔HBM:
this kernel tiles Q into MXU-aligned blocks held in VMEM, streams K/V
blocks through, and keeps the online-softmax running statistics (m, l) and
the output accumulator in VMEM scratch for the whole K/V sweep.

Grid: ``(B, Hq, Sq/bq, Skv/bk)`` — the trailing (minor) grid axis is
sequential on TPU, so scratch carries state across the K/V sweep of each
Q block.  GQA folds the head-group mapping into the K/V index_map.

Forward only: the serving path (the paper's setting — inference) uses it
directly; training uses the reference path (XLA fuses adequately there and
the dry-run needs portable HLO).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def _flash_fwd_kernel(
    q_ref, k_ref, v_ref,          # VMEM blocks
    o_ref,                        # output block
    m_scr, l_scr, acc_scr,        # VMEM scratch: (bq,1), (bq,1), (bq, hdv)
    *,
    scale: float,
    causal: bool,
    window: int,
    softcap: float,
    bq: int,
    bk: int,
    kv_len: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_idx = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_idx = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # skip blocks that the causal/window structure masks out entirely
    block_needed = True
    if causal:
        block_needed = jnp.logical_and(block_needed, ik * bk <= iq * bq + bq - 1)
    if window:
        block_needed = jnp.logical_and(block_needed, (iq * bq) - (ik * bk + bk - 1) < window)

    @pl.when(block_needed)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, hdv)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        if softcap:
            s = softcap * jnp.tanh(s / softcap)

        mask = k_idx < kv_len
        if causal:
            mask &= k_idx <= q_idx
        if window:
            mask &= q_idx - k_idx < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                             # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                          # (bq, bk)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        l = jnp.where(l == 0.0, 1.0, l)                 # fully-masked rows -> 0
        o_ref[0, 0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,   # (B, Hq, Sq, hd)
    k: jax.Array,   # (B, Hkv, Skv, hd)
    v: jax.Array,   # (B, Hkv, Skv, hdv)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Sq, hd = q.shape
    _, Hkv, Skv, hdv = v.shape
    rep = Hq // Hkv
    scale = scale if scale is not None else hd ** -0.5
    bq = min(block_q, Sq)
    bk = min(block_k, Skv)
    if Sq % bq or Skv % bk:
        raise ValueError(f"seq lens ({Sq},{Skv}) must divide blocks ({bq},{bk})")

    grid = (B, Hq, Sq // bq, Skv // bk)
    kern = functools.partial(
        _flash_fwd_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, bq=bq, bk=bk, kv_len=Skv)

    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, iq, ik, rep=rep: (b, h // rep, ik, 0)),
            pl.BlockSpec((1, 1, bk, hdv), lambda b, h, iq, ik, rep=rep: (b, h // rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hdv), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, hdv), q.dtype),
        scratch_shapes=[
            _vmem((bq, 1)),
            _vmem((bq, 1)),
            _vmem((bq, hdv)),
        ],
        interpret=interpret,
    )(q, k, v)


def _vmem(shape):
    """f32 VMEM scratch (works in interpret mode on CPU too)."""
    from jax.experimental.pallas import tpu as pltpu

    return pltpu.VMEM(shape, jnp.float32)
