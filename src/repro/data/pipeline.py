"""Deterministic sharded LM data pipeline with exact skip-to-step resume.

Production framing: every host in a multi-pod job constructs the same
pipeline object; each host materialises only its shard of the global batch
(`host_slice`), and the *data state* (a single step counter + seed) is part
of the checkpoint, so restart — on the same or a different host count — is
bitwise reproducible (counter-based stateless generation, no RNG state to
migrate).

Source: synthetic token streams (a fixed-seed mixture of Zipf-distributed
unigrams and order-2 Markov chains), which is the standard offline-
container stand-in for a tokenised corpus.  The interface (``__iter__`` /
``at_step`` / ``state``) is what a real corpus-backed pipeline would
implement; nothing downstream knows the difference.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # multi-host sharding: this host materialises rows
    # [host_id * global_batch // n_hosts, (host_id+1) * global_batch // n_hosts)
    n_hosts: int = 1
    host_id: int = 0

    def __post_init__(self):
        if self.global_batch % self.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        if not (0 <= self.host_id < self.n_hosts):
            raise ValueError("host_id out of range")

    @property
    def host_batch(self) -> int:
        return self.global_batch // self.n_hosts


@dataclasses.dataclass
class DataState:
    """Everything needed to resume the stream exactly (checkpointed)."""
    step: int = 0
    seed: int = 0

    def to_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    @classmethod
    def from_dict(cls, d: dict) -> "DataState":
        return cls(step=int(d["step"]), seed=int(d["seed"]))


class LMDataPipeline:
    """Counter-based (stateless) batch generation: batch(step, row) depends
    only on (seed, step, global row index) — NOT on host count — so elastic
    re-sharding to a different host/device count replays identical tokens.
    """

    def __init__(self, cfg: DataConfig, state: Optional[DataState] = None):
        self.cfg = cfg
        self.state = state or DataState(seed=cfg.seed)
        # Zipf-ish unigram + order-2 Markov mixing weights, fixed by seed
        root = np.random.default_rng(cfg.seed)
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        self._unigram = 1.0 / ranks ** 1.1
        self._unigram /= self._unigram.sum()
        self._mix = root.integers(1, cfg.vocab_size, size=64, dtype=np.int64)

    # -- core: one global row, pure function of (seed, step, row) ----------
    def _row(self, step: int, row: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, row]))
        toks = rng.choice(cfg.vocab_size, size=cfg.seq_len, p=self._unigram)
        # order-2 structure: x[t] correlates with a hash of the two previous
        # tokens on a fixed fraction of positions (gives a learnable signal)
        structured = rng.random(cfg.seq_len) < 0.5
        for t in range(2, cfg.seq_len):
            if structured[t]:
                h = (toks[t - 1] * 31 + toks[t - 2] * 17
                     + self._mix[t % len(self._mix)])
                toks[t] = h % cfg.vocab_size
        return toks.astype(np.int32)

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """This host's shard of the global batch for ``step``."""
        cfg = self.cfg
        lo = cfg.host_id * cfg.host_batch
        rows = [self._row(step, lo + i) for i in range(cfg.host_batch)]
        return {"tokens": np.stack(rows)}

    def global_batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Full global batch (single-host testing / verification)."""
        rows = [self._row(step, i) for i in range(self.cfg.global_batch)]
        return {"tokens": np.stack(rows)}

    # -- iteration / resume -------------------------------------------------
    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(self.state.step)
            self.state.step += 1

    def at_step(self, step: int) -> "LMDataPipeline":
        """Skip-to-step resume (O(1): no stream replay needed)."""
        self.state.step = step
        return self
