"""The reporting/perf tooling is load-bearing for EXPERIMENTS.md — test it."""
import os

import pytest

from conftest import REPO

DRYRUN = os.path.join(REPO, "experiments", "dryrun")
HLO = os.path.join(DRYRUN, "gemma3-27b__prefill_32k__single.hlo.txt")


@pytest.mark.skipif(not os.path.isdir(DRYRUN), reason="no dry-run results")
def test_report_tables_generate():
    from benchmarks.report import dryrun_table, load, roofline_table, summary

    recs = load()
    assert len(recs) == 80
    s = summary(recs)
    assert "80" in s
    t = dryrun_table(recs)
    assert t.count("\n") >= 80
    r = roofline_table(recs)
    assert "compute_s" in r


@pytest.mark.skipif(not os.path.exists(HLO), reason="no saved HLO")
def test_flash_adjust_reduces_memory_term():
    from benchmarks.perf_flash_adjust import run

    out = run("gemma3-27b", "prefill_32k", "single", verbose=False)
    assert out["memory_s_flash"] < out["memory_s_ref"]
    assert out["score_class_gib"] > 0
    assert out["speedup"] >= 1.0
    assert out["step_s_flash"] <= out["step_s_ref"]


def test_cpu_promotion_detector_on_synthetic_hlo():
    from repro.roofline.hlo import cpu_bf16_promotion_bytes_serving

    hlo = """
HloModule t

ENTRY %main (p: bf16[4096,8192]) -> f32[4096,8192] {
  %p = bf16[4096,8192]{1,0} parameter(0)
  ROOT %c = f32[4096,8192]{1,0} convert(%p)
}
"""
    b = cpu_bf16_promotion_bytes_serving(hlo)
    assert b == 4096 * 8192 * 4
