"""Model-library consistency: decode-with-cache == full forward, MoE
invariants, scan grouping, attention flavours."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config, reduce_config
from repro.models import transformer as T
from repro.models import modules as M
from repro.models.moe import apply_moe, init_moe, router_aux_loss


def _decode_matches_prefill(arch, steps=4, seq=16, atol=5e-2):
    """Greedy decode token-by-token must match teacher-forced prefill
    logits — the KV cache (ring buffers, SSM states, RG-LRU states) carries
    exactly the information the full forward sees."""
    cfg = reduce_config(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key, param_dtype=jnp.float32)
    toks = jax.random.randint(key, (1, seq + steps), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :seq]}
    if cfg.family == "vlm":
        batch["image_embeds"] = jax.random.normal(
            key, (1, cfg.n_frontend_tokens, cfg.d_model), jnp.float32) * 0.02

    # incremental: prefill then decode the next `steps` tokens
    logits, cache = T.prefill(params, cfg, batch, kv_cap=seq + steps,
                              compute_dtype=jnp.float32)
    inc = [logits]
    for s in range(steps - 1):
        tok = toks[:, seq + s]
        pos = jnp.full((1,), seq + s, jnp.int32)
        logits, cache = T.decode_step(params, cfg, cache, tok, pos,
                                      compute_dtype=jnp.float32)
        inc.append(logits)

    # oracle: full prefill over the longer prefix each time
    for s in range(steps):
        full_batch = dict(batch)
        full_batch["tokens"] = toks[:, :seq + s]
        ref, _ = T.prefill(params, cfg, full_batch, kv_cap=seq + steps,
                           compute_dtype=jnp.float32)
        np.testing.assert_allclose(
            np.asarray(inc[s], np.float32), np.asarray(ref, np.float32),
            atol=atol, rtol=atol)


@pytest.mark.parametrize("arch", [
    "qwen2.5-3b",           # dense GQA + qkv bias
    "gemma2-9b",            # local/global alternating + softcaps + post-norm
    "mamba2-130m",          # pure SSM
    "recurrentgemma-9b",    # RG-LRU hybrid
    "deepseek-v2-236b",     # MLA + MoE
    "llama-3.2-vision-90b", # cross-attn VLM
    "gpt-j",                # parallel block
])
def test_decode_matches_full_forward(arch):
    _decode_matches_prefill(arch)


def test_scan_groups_match_depth():
    """Grouped-scan stacks must cover every layer: group repeats × period
    + remainder == n_layers, kinds cycled correctly."""
    for arch in ("gemma2-9b", "gemma3-27b", "recurrentgemma-9b",
                 "qwen3-moe-30b-a3b", "deepseek-v2-236b"):
        cfg = get_config(arch)
        groups = T.build_groups(cfg)
        total = sum(len(g.units) * g.repeats for g in groups)
        assert total == cfg.n_layers, arch
        flat = []
        for g in groups:
            flat += [u[0] for u in g.units] * g.repeats
        assert tuple(flat) == cfg.layer_kinds, arch


def test_param_count_deepseek_order():
    """deepseek-v2 ≈ 236B total / ~21B active."""
    cfg = get_config("deepseek-v2-236b")
    total = cfg.param_count()
    active = cfg.active_param_count()
    assert 2.0e11 < total < 2.8e11, total
    assert 1.2e10 < active < 3.0e10, active


def test_param_count_dense_order():
    for arch, lo, hi in (("qwen2.5-3b", 2.5e9, 4.0e9),
                         ("gemma2-9b", 8e9, 11.5e9),
                         ("minitron-8b", 7e9, 10e9),
                         ("mamba2-130m", 1.0e8, 1.8e8)):
        n = get_config(arch).param_count()
        assert lo < n < hi, (arch, n)


def test_moe_router_mass_and_aux():
    cfg = reduce_config(get_config("qwen3-moe-30b-a3b"))
    key = jax.random.PRNGKey(0)
    p = init_moe(key, cfg, dtype=jnp.float32)
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32)
    out = apply_moe(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    aux = router_aux_loss(p, x, cfg)
    # balanced-routing lower bound: aux >= 1 (perfect balance) for the
    # standard load-balancing loss normalisation
    assert float(aux) > 0.5


def test_moe_permutation_invariance_over_batch():
    """MoE output for a token must not depend on other tokens in the batch
    (dense capacity-free dispatch)."""
    cfg = reduce_config(get_config("qwen3-moe-30b-a3b"))
    key = jax.random.PRNGKey(1)
    p = init_moe(key, cfg, dtype=jnp.float32)
    x = jax.random.normal(key, (2, 4, cfg.d_model), jnp.float32)
    out = apply_moe(p, x, cfg)
    xp = x[::-1]
    outp = apply_moe(p, xp, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(outp[::-1]),
                               atol=1e-5)


def test_local_global_window_respected():
    """gemma-style local layers must not see beyond the window."""
    cfg = reduce_config(get_config("gemma2-9b"))
    assert "local" in cfg.layer_kinds
    assert cfg.window > 0


def test_mla_cache_is_latent():
    """MLA KV cache stores the compressed latent (kv_lora + rope dims), not
    full per-head K/V — the memory saving that defines MLA."""
    cfg = reduce_config(get_config("deepseek-v2-236b"))
    cache = T.init_cache(cfg, batch=1, kv_len=8)
    leaves = jax.tree_util.tree_flatten_with_path(cache)[0]
    names = {str(kp[-1].key) if hasattr(kp[-1], "key") else "" for kp, _ in leaves}
    assert "ckv" in names or any("ckv" in str(kp) for kp, _ in leaves)
    # no full k/v tensors with n_heads axis
    for kp, leaf in leaves:
        nm = str(getattr(kp[-1], "key", ""))
        if nm in ("k", "v"):
            raise AssertionError("MLA cache must not hold full K/V")


def test_softcap_bounds_logits():
    cfg = reduce_config(get_config("gemma2-9b"))
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    # blow up the lm_head to force big logits
    params["embed"]["tok"] = params["embed"]["tok"] * 50.0
    batch = {"tokens": jax.random.randint(key, (1, 8), 0, cfg.vocab_size)}
    logits, _ = T.prefill(params, cfg, batch, kv_cap=8,
                          compute_dtype=jnp.float32)
    assert float(jnp.abs(logits).max()) <= cfg.final_softcap + 1e-3


def test_rmsnorm_normalizes():
    cfg = reduce_config(get_config("qwen2.5-3b"))
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 4, cfg.d_model), jnp.float32) * 3 + 1
    p = M.init_norm(key, cfg)
    y = M.apply_norm(p, x)
    # rms of output ~1 (weight init 1)
    rms = jnp.sqrt(jnp.mean(y.astype(jnp.float32) ** 2, axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, atol=0.2)


def test_whisper_encoder_decoder_wiring():
    cfg = reduce_config(get_config("whisper-large-v3"))
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    assert "encoder" in params
    batch = {
        "tokens": jax.random.randint(key, (2, 8), 0, cfg.vocab_size),
        "frames": jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32),
    }
    loss, _ = T.loss_fn(params, cfg, batch, compute_dtype=jnp.float32)
    assert np.isfinite(float(loss))
    # decoder output must depend on encoder input (cross-attention wired)
    batch2 = dict(batch)
    batch2["frames"] = batch["frames"] * 0.0
    loss2, _ = T.loss_fn(params, cfg, batch2, compute_dtype=jnp.float32)
    assert abs(float(loss) - float(loss2)) > 1e-6
