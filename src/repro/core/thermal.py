"""Thermal + ReRAM-noise models for 3D-HI (paper §4.3, eqs 16-19).

Vertical heat flow (eq. 16): T(n,k) = Σᵢ₌₁ᵏ (Pₙᵢ Σⱼ₌₁ⁱ Rⱼ) + R_b Σᵢ Pₙᵢ
Horizontal spread (eq. 17): ΔT(k) = maxₙ T(n,k) − minₙ T(n,k)
Combined objective (eq. 18): T(λ) = max T(n,k) · max ΔT(k)
ReRAM thermal noise (eq. 19): σ = √(4 G k_B T_ReRAM F) / V

The 3D-HI MOO (eq. 20) adds T(λ) and Noise(λ) to the (μ, σ) utilisation
objectives.  The same column model quantifies why the original HAIMA /
TransPIM 3-D stacks exceed DRAM's 95 °C ceiling (Fig. 11): eight 3.138 W
compute units per bank on a 53.15 mm² HBM die.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.core import chiplets as C
from repro.core.placement import Placement

AMBIENT_C = 45.0
R_VERT = 0.18        # K/W per tier (TSV stack, [59])
R_BASE = 0.35        # K/W heat-sink/base resistance
K_B = 1.380649e-23
RERAM_G = 1.0 / 20e3  # ~20 kΩ LRS conductance
RERAM_V = 0.3
RERAM_F = 0.5e9


@dataclasses.dataclass
class ThermalReport:
    peak_c: float
    delta_c: float
    objective: float          # eq. 18
    per_tier_peak: list
    reram_noise_sigma: float  # eq. 19
    dram_feasible: bool       # < 95 °C


def _power_of(t: str) -> float:
    return {
        "SM": C.SM.power_w, "MC": C.MC.power_w, "ReRAM": C.RERAM.power_w,
        "DRAM": 1.1, "SRAM": 1.2, "ACU": 0.9, "HOST": 6.0,
    }.get(t, 0.5)


def stack_columns(tiers: list[list[str]]) -> np.ndarray:
    """tiers: list (bottom→top, index 1 = closest to sink) of per-column
    chiplet types; returns power matrix P[n, i]."""
    n_cols = max(len(t) for t in tiers)
    P = np.zeros((n_cols, len(tiers)))
    for i, tier in enumerate(tiers):
        for n, t in enumerate(tier):
            P[n, i] = _power_of(t)
    return P


def thermal_eval(tiers: list[list[str]]) -> ThermalReport:
    P = stack_columns(tiers)                      # (n_cols, n_tiers)
    n_cols, n_tiers = P.shape
    T = np.zeros_like(P)
    for k in range(n_tiers):
        for n in range(n_cols):
            # eq. 16: vertical column model
            acc = 0.0
            for i in range(k + 1):
                acc += P[n, i] * (R_VERT * (i + 1))
            acc += R_BASE * P[n, : k + 1].sum()
            # horizontal coupling: neighbours' mean power leaks in
            lateral = 0.12 * (P[:, k].mean())
            T[n, k] = AMBIENT_C + acc + lateral
    per_tier_peak = T.max(axis=0)
    delta = T.max(axis=0) - T.min(axis=0)         # eq. 17
    peak = float(T.max())
    objective = peak * float(delta.max())         # eq. 18
    # ReRAM noise at the hottest ReRAM tier (eq. 19)
    reram_T = AMBIENT_C + 273.15
    for i, tier in enumerate(tiers):
        if any(t == "ReRAM" for t in tier):
            reram_T = max(reram_T, float(T[:, i].max()) + 273.15)
    sigma = math.sqrt(4 * RERAM_G * K_B * reram_T * RERAM_F) / RERAM_V
    return ThermalReport(peak, float(delta.max()), objective,
                         per_tier_peak.tolist(), sigma, peak < C.DRAM.max_temp_c)


def tiers_from_placement(p: Placement, n_tiers: int = 2) -> list[list[str]]:
    """Split a 2.5D placement into vertical tiers for 3D-HI: SM-MC tiers and
    ReRAM tiers may not share a tier (technology constraint, §4.3)."""
    cmos = [t for t in p.types if t in ("SM", "MC", "DRAM", "HOST", "ACU", "SRAM")]
    reram = [t for t in p.types if t == "ReRAM"]
    tiers: list[list[str]] = [[] for _ in range(n_tiers)]
    for i, t in enumerate(cmos):
        tiers[i % max(n_tiers - 1, 1)].append(t)
    tiers[-1] = reram or ["ReRAM"]
    return tiers


def hbm_pim_stack_report(n_tiers: int = 8, units_per_bank: int = 8,
                         unit_w: float = 3.138, banks: int = 16,
                         die_mm2: float = 53.15,
                         concurrent_frac: float = 0.125) -> ThermalReport:
    """Fig-11 baseline check: original HAIMA/TransPIM 3-D HBM-PIM stacks.
    Eight 3.138 W units/bank drives power density an order of magnitude
    past a GPU's; the column model puts the stack far above 95 °C.
    ``concurrent_frac``: fraction of banks concurrently active (cf. the
    simulator's ``orig_bank_cap``)."""
    per_die_w = units_per_bank * unit_w * banks * concurrent_frac
    tiers = [["PIMDIE"] * 4 for _ in range(n_tiers)]
    P = np.full((4, n_tiers), per_die_w / 4)
    T = np.zeros_like(P)
    for k in range(n_tiers):
        for n in range(P.shape[0]):
            acc = sum(P[n, i] * (R_VERT * (i + 1)) for i in range(k + 1))
            acc += R_BASE * P[n, : k + 1].sum()
            T[n, k] = AMBIENT_C + acc
    peak = float(T.max())
    delta = float((T.max(0) - T.min(0)).max())
    sigma = math.sqrt(4 * RERAM_G * K_B * (peak + 273.15) * RERAM_F) / RERAM_V
    return ThermalReport(peak, delta, peak * max(delta, 1e-9),
                         T.max(0).tolist(), sigma, peak < C.DRAM.max_temp_c)


def baseline_stack_report(kind: str) -> ThermalReport:
    """Fig-11 steady-state temperature of the original 3-D baselines.

    HAIMA: up to eight 3.138 W compute units per bank on a 53.15 mm² HBM2
    die; TransPIM: 8 HBM stacks with in-bank logic, thermal resistance
    growing up the stack (§4.3).  Paper: ≥120 °C, max 131 °C.
    """
    if kind == "haima":
        # 8 units/bank, 4-of-16 banks concurrent (= simulator orig_bank_cap)
        return hbm_pim_stack_report(n_tiers=4, units_per_bank=8,
                                    concurrent_frac=0.25)
    if kind == "transpim":
        return hbm_pim_stack_report(n_tiers=8, units_per_bank=6,
                                    concurrent_frac=0.125)
    raise ValueError(f"unknown baseline {kind!r}")


def hi3d_stack_report(n_chiplets: int, n_tiers: int = 2) -> ThermalReport:
    """3D-HI thermal report from the Table-2 allocation placed on tiers
    (SM-MC tiers below, ReRAM tier on top — §4.3 technology constraint)."""
    from repro.core.placement import initial_placement

    return thermal_eval(tiers_from_placement(
        initial_placement(n_chiplets), n_tiers))


def noise_objective(report: ThermalReport) -> float:
    return report.reram_noise_sigma


def moo_objectives_3d(p: Placement, noi_mu: float, noi_sigma: float,
                      n_tiers: int = 2) -> tuple:
    """Eq. 20: (μ, σ, T(λ), Noise(λ))."""
    th = thermal_eval(tiers_from_placement(p, n_tiers))
    return (noi_mu, noi_sigma, th.objective, th.reram_noise_sigma)
