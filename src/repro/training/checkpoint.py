"""Atomic, resumable checkpointing for params / optimizer / data state.

Fault-tolerance contract (assignment deliverable-2 axis):

- **Atomic**: write to ``<dir>/tmp.<step>`` then ``os.replace`` into
  ``step_<n>`` and update the ``LATEST`` pointer file last — a host dying
  mid-save can never corrupt the latest restorable state.
- **Bitwise resume**: params + both Adam moments + step counter + data
  state round-trip exactly (fp32 npz) — verified by
  ``tests/test_training.py::test_checkpoint_resume_bitwise``.
- **Preemption**: ``PreemptionHandler`` converts SIGTERM (the TPU-pod
  eviction signal) into a save-at-next-step-boundary request.
- **Elastic**: checkpoints are stored *unsharded* (gathered); restore
  re-shards onto whatever mesh the new job brings up, so a 512-chip job
  can resume on 256 chips (tested 8→4 fake devices).
- **Retention**: keep the newest ``keep`` checkpoints, delete older ones.
"""
from __future__ import annotations

import json
import os
import signal
import threading
from typing import Any, Optional

import jax
import numpy as np

from repro.ckpt import (atomic_save_dir, flatten_tree, gc_dirs, read_latest,
                        unflatten_tree)

# pytree <-> flat dict-of-arrays: shared with the serving checkpointer
# (repro.ckpt) — kept under the old private names for callers/tests
_flatten = flatten_tree
_unflatten = unflatten_tree


# ---------------------------------------------------------------------------
# save / restore
# ---------------------------------------------------------------------------

def save_checkpoint(ckpt_dir: str, step: int, *, params, opt_state=None,
                    data_state: Optional[dict] = None,
                    extra: Optional[dict] = None, keep: int = 3) -> str:
    """Atomic save; returns the final checkpoint path."""
    def write(tmp: str) -> None:
        np.savez(os.path.join(tmp, "params.npz"), **_flatten(params))
        if opt_state is not None:
            np.savez(os.path.join(tmp, "opt_state.npz"), **_flatten(opt_state))
        meta = {"step": step, "data_state": data_state or {},
                "extra": extra or {}}
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)

    return atomic_save_dir(ckpt_dir, f"step_{step:08d}", write,
                           prefix="step_", keep=keep)


def latest_step(ckpt_dir: str) -> Optional[int]:
    name = read_latest(ckpt_dir)
    return None if name is None else int(name.split("_")[-1])


def restore_checkpoint(ckpt_dir: str, *, params_template, opt_template=None,
                       step: Optional[int] = None,
                       shardings=None, opt_shardings=None):
    """Restore (params, opt_state, meta).  ``shardings`` (optional pytrees of
    NamedSharding) re-shard onto the *current* mesh — the elastic-resume
    path: the checkpoint itself is mesh-agnostic."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")

    with np.load(os.path.join(path, "params.npz")) as z:
        params = _unflatten(params_template, dict(z))
    if shardings is not None:
        params = jax.device_put(params, shardings)

    opt_state = None
    opt_path = os.path.join(path, "opt_state.npz")
    if opt_template is not None and os.path.exists(opt_path):
        with np.load(opt_path) as z:
            opt_state = _unflatten(opt_template, dict(z))
        if opt_shardings is not None:
            opt_state = jax.device_put(opt_state, opt_shardings)

    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    return params, opt_state, meta


def _gc(ckpt_dir: str, keep: int):
    gc_dirs(ckpt_dir, "step_", keep)


# ---------------------------------------------------------------------------
# preemption
# ---------------------------------------------------------------------------

class PreemptionHandler:
    """SIGTERM → save-at-next-step-boundary.  The training loop polls
    ``should_save`` once per step; the signal handler itself only flips a
    flag (async-signal-safe)."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._installed = []
        for s in signals:
            try:
                prev = signal.signal(s, self._on_signal)
                self._installed.append((s, prev))
            except ValueError:  # non-main thread (tests)
                pass

    def _on_signal(self, signum, frame):
        self._flag.set()

    @property
    def should_save(self) -> bool:
        return self._flag.is_set()

    def reset(self):
        self._flag.clear()

    def uninstall(self):
        for s, prev in self._installed:
            signal.signal(s, prev)
        self._installed = []
