"""The reporting/perf tooling is load-bearing for EXPERIMENTS.md — test it."""
import os

import pytest

from conftest import REPO

DRYRUN = os.path.join(REPO, "experiments", "dryrun")
HLO = os.path.join(DRYRUN, "gemma3-27b__prefill_32k__single.hlo.txt")


@pytest.mark.skipif(not os.path.isdir(DRYRUN), reason="no dry-run results")
def test_report_tables_generate():
    from benchmarks.report import dryrun_table, load, roofline_table, summary

    recs = load()
    assert len(recs) == 80
    s = summary(recs)
    assert "80" in s
    t = dryrun_table(recs)
    assert t.count("\n") >= 80
    r = roofline_table(recs)
    assert "compute_s" in r


@pytest.mark.skipif(not os.path.exists(HLO), reason="no saved HLO")
def test_flash_adjust_reduces_memory_term():
    from benchmarks.perf_flash_adjust import run

    out = run("gemma3-27b", "prefill_32k", "single", verbose=False)
    assert out["memory_s_flash"] < out["memory_s_ref"]
    assert out["score_class_gib"] > 0
    assert out["speedup"] >= 1.0
    assert out["step_s_flash"] <= out["step_s_ref"]


def test_cpu_promotion_detector_on_synthetic_hlo():
    from repro.roofline.hlo import cpu_bf16_promotion_bytes_serving

    hlo = """
HloModule t

ENTRY %main (p: bf16[4096,8192]) -> f32[4096,8192] {
  %p = bf16[4096,8192]{1,0} parameter(0)
  ROOT %c = f32[4096,8192]{1,0} convert(%p)
}
"""
    b = cpu_bf16_promotion_bytes_serving(hlo)
    assert b == 4096 * 8192 * 4


# ---------------------------------------------------------------------------
# resilience section: must degrade gracefully on missing/partial records
# ---------------------------------------------------------------------------

def _patch_experiments(monkeypatch, tmp_path):
    """Point report.py's path anchor at an empty experiments dir."""
    import benchmarks.report as report
    monkeypatch.setattr(report, "DRYRUN", str(tmp_path / "dryrun"))
    return tmp_path / "BENCH_resilience.json"


def test_resilience_table_missing_file(monkeypatch, tmp_path):
    from benchmarks.report import resilience_table
    _patch_experiments(monkeypatch, tmp_path)
    out = resilience_table()
    assert "no BENCH_resilience.json" in out


def test_resilience_table_malformed_json(monkeypatch, tmp_path):
    from benchmarks.report import resilience_table
    path = _patch_experiments(monkeypatch, tmp_path)
    path.write_text("{not json", encoding="utf-8")
    out = resilience_table()
    assert "malformed" in out


def test_resilience_table_partial_record(monkeypatch, tmp_path):
    """A half-written record (top-level keys only, sections absent or
    None-valued) renders per-section notices — never a traceback."""
    import json
    from benchmarks.report import resilience_table
    path = _patch_experiments(monkeypatch, tmp_path)
    path.write_text(json.dumps({
        "bench": "perf_resilience", "smoke": True, "chiplets": 36,
        "prompt_len": 64, "gen_len": 16, "batch": 4,
        "zoo_faults": {"cells": []}, "noi_fault_search": None,
    }), encoding="utf-8")
    out = resilience_table()
    assert "zoo_faults section missing" in out
    assert "noi_fault_search section missing" in out
    assert "engine_overload section missing" in out


def test_recovery_table_missing_file(monkeypatch, tmp_path):
    from benchmarks.report import recovery_table
    _patch_experiments(monkeypatch, tmp_path)
    out = recovery_table()
    assert "no BENCH_recovery.json" in out


def test_recovery_table_renders_record(monkeypatch, tmp_path):
    """Renders both the chaos and MTTR sections, including the
    engine-unsupported enc-dec row and the '—' a disconnected design
    leaves behind."""
    import json
    from benchmarks.report import recovery_table
    _patch_experiments(monkeypatch, tmp_path)
    kill = {"kind": "mid_decode", "kill_at": 3, "match": True, "lost": 0,
            "duplicated": 0, "checkpoints_written": 1, "restores": 1,
            "replayed_requests": 1}
    (tmp_path / "BENCH_recovery.json").write_text(json.dumps({
        "bench": "perf_recovery", "smoke": False, "chiplets": 36,
        "prompt_len": 64, "gen_len": 16, "batch": 8,
        "chaos": {"cells": [
            {"model": "qwen2.5-3b", "kv_bits": None, "supported": True,
             "kills": [kill]},
            {"model": "bart-large", "supported": False,
             "reason": "enc-dec"},
        ]},
        "mttr_noi_search": {"cells": [
            {"model": "qwen2.5-3b",
             "oblivious": {"worst_total_k1": None, "n_disconnected_k1": 3},
             "aware": {"worst_total_k1": 0.5, "n_disconnected_k1": 0,
                       "ckpt_overhead": 1.01},
             "gain_worst_k1": None, "aware_survives_k1": True},
        ]},
    }), encoding="utf-8")
    out = recovery_table()
    assert "mid_decode@3" in out and "| yes |" in out
    assert "engine-unsupported" in out
    assert "—" in out and "∞" in out


def test_report_main_tolerates_missing_experiments_dir(monkeypatch,
                                                       tmp_path, capsys):
    """A checkout with no experiments/ at all must render a full report of
    placeholders — no traceback, every section header present."""
    import benchmarks.report as report
    monkeypatch.setattr(report, "DRYRUN",
                        str(tmp_path / "experiments" / "dryrun"))
    report.main()
    captured = capsys.readouterr()
    assert "Crash recovery" in captured.out
    assert "no BENCH_recovery.json" in captured.out
    assert "no BENCH_resilience.json" in captured.out
    assert "Measured-cost calibration" in captured.out
    assert "no BENCH_calib.json" in captured.out
    assert "directory missing" in captured.err + captured.out


def test_calib_table_missing_and_malformed(monkeypatch, tmp_path):
    from benchmarks.report import calib_table
    _patch_experiments(monkeypatch, tmp_path)
    assert "no BENCH_calib.json" in calib_table()
    (tmp_path / "BENCH_calib.json").write_text("{not json",
                                               encoding="utf-8")
    assert "malformed" in calib_table()


def test_calib_table_renders_record_without_cosim(monkeypatch, tmp_path):
    """Renders the fit table and error bar; with no BENCH_cosim.json next
    to it, the headline pairing degrades to a notice, not a crash."""
    import json

    from benchmarks.report import calib_table
    _patch_experiments(monkeypatch, tmp_path)
    fit = {"kind": "decode_attn", "term": "bytes", "intercept_s": 1e-5,
           "rate": 1e9, "rate_ci95_rel": 0.1, "r2": 0.99, "n_train": 6,
           "n_heldout": 3, "heldout_max_rel_err": 0.12,
           "heldout_mean_rel_err": 0.05, "flops_per_unit": 2.0,
           "ref_term": 1e6, "ref_seconds": 1e-3}
    err = {"plane": "sm", "term": "bytes", "ref_term": 1e6,
           "measured_s": 1e-3, "fit_rel_err_at_ref": 0.01,
           "analytical_s": 1e-4, "log10_measured_over_analytical": 1.0,
           "intercept_s": 1e-5, "rate": 1e9, "rate_ci95_rel": 0.1,
           "heldout_max_rel_err": 0.12, "heldout_mean_rel_err": 0.05,
           "n_train": 6, "n_heldout": 3}
    rec = {
        "bench": "calib", "backend": "cpu", "interpret": True,
        "smoke": True, "tolerance_rel": 0.75, "n_samples": 9,
        "error_bar_rel": 0.12,
        "table": {"version": 1, "backend": "cpu", "interpret": True,
                  "meta": {}, "fits": {"decode_attn": fit}},
        "phase_errors": {"decode_attn": err},
        "calib": {"default": {"sm_efficiency": 1e-2, "reram_fill": 3e-4},
                  "measured": {"sm_efficiency": 1e-4,
                               "reram_fill": 1e-5}},
        "cosim": {"model": "gpt-j", "chiplets": 64,
                  "default": {"ttft_ms": 100.0, "decode_step_ms": 46.0,
                              "decode_tok_s": 170.0},
                  "measured": {"ttft_ms": 200.0, "decode_step_ms": 92.0,
                               "decode_tok_s": 85.0},
                  "decode_step_rel_delta": 1.0},
        "engine_trace": {"trace_iterations": 5, "trace_prefill_s": 0.1,
                         "trace_decode_s": 0.2, "trace_d2h_s": 0.01,
                         "trace_decode_step_s": 0.04,
                         "trace_decode_step_p50_s": 0.04,
                         "trace_decode_step_p95_s": 0.05,
                         "mix_measured_step_s": 0.04},
    }
    (tmp_path / "BENCH_calib.json").write_text(json.dumps(rec),
                                               encoding="utf-8")
    out = calib_table()
    assert "decode_attn" in out and "±12%" in out
    assert "no BENCH_cosim.json" in out


def test_resilience_table_renders_full_record(monkeypatch, tmp_path):
    """The table renders the real benchmark record, including the None
    entries a disconnected sweep writes (shown as '—')."""
    import json
    import subprocess
    import sys

    from benchmarks.report import resilience_table
    path = _patch_experiments(monkeypatch, tmp_path)
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    subprocess.run(
        [sys.executable, "-m", "benchmarks.perf_resilience", "--smoke",
         "--out", str(path)],
        check=True, cwd=REPO, env=env, capture_output=True, timeout=600)
    rec = json.loads(path.read_text())
    from benchmarks.perf_resilience import check_schema
    check_schema(rec)
    out = resilience_table()
    assert "Fault-aware vs fault-oblivious" in out
    assert "Engine overload" in out
    assert "goodput" in out


def test_report_renders_null_latencies_as_dash():
    """Empty-class percentiles are recorded as null (never 0.0); the
    table renderers must print them as '—', not format None (TypeError)
    or a fake 0 ms latency."""
    from benchmarks.report import _ms, _opt

    assert _ms(None, "{:.1f}") == "—"
    assert _ms(0.0125, "{:.1f}") == "12.5"
    assert _opt(None, "{:.3f}") == "—"
    assert _opt(2.5, "{:.1f}×") == "2.5×"


def test_capacity_percentiles_of_empty_class_are_null():
    """perf_capacity._pcts on an empty sample returns (None, None, None)
    — the BENCH record holds nulls, never zeros that render as real
    latencies."""
    from benchmarks.perf_capacity import _pcts

    assert _pcts([]) == (None, None, None)
    p50, p95, p99 = _pcts([0.1, 0.2, 0.3])
    assert 0.1 <= p50 <= p95 <= p99 <= 0.3
