"""Transformer → per-phase inter-chiplet traffic F_ij(t) (paper §3.2).

The paper profiles inference workloads (nvidia-smi + PyTorch traces) to get
the traffic matrix each NoI candidate is evaluated under.  We derive the
same phase structure analytically from the model configs — the operation
counts are exact because our Plane-A JAX models implement the same graphs.

Phases per encoder/decoder block (Fig. 2a):
  ①  input embedding (one-time)    ReRAM_i → ReRAM_{i+1} pipeline
  ②③ W_{K,Q,V} load + KQV compute  DRAM→MC→SM (many-to-few, both ways)
  ④  score (QKᵀ, softmax, ·V)      SM↔SM within cluster, SM→MC spill
  ⑤  feed-forward                   ReRAM macro pipeline; MC → ReRAM head

A workload descriptor captures exactly what the traffic model needs —
dims, heads (MQA/GQA collapse the K/V share), enc/dec structure, and the
parallel MHA-FF flag (GPT-J) which overlaps ④ and ⑤.

Beyond the single fixed-length forward pass (``transformer_phases``, the
Table-4 calibration surface — never changed by the generation model), the
module derives full *generation* episodes:

- ``prefill_phases``    — the forward pass over the prompt **plus** the
  explicit KV-cache write-back traffic (SM→MC→DRAM) that a serving run
  performs so decode can read the cache later;
- ``decode_step_phases`` — one autoregressive step at a given KV position:
  per-token KQV (N=1, weights re-streamed), score over the *cached* KV
  (DRAM→MC→SM read traffic growing linearly with position, GQA-aware via
  ``kv_frac``), cross-attention over the frozen encoder KV (enc-dec), FF
  and lm_head per token.  Decode phases repeat over the *decoder* stack
  only (``n_dec_layers``).

The decode step is **batched**: ``decode_step_phases(w, kv_pos, batch=B)``
models one engine iteration serving ``B`` active KV slots.  Weight
streaming (W_KQV, the attention output projection, the cross projection)
is paid **once per step** — the continuous-batching engine amortises it
across the batch — while everything per-slot (activations, KV-cache reads
at each slot's own position, KV row commits, FF/lm_head work) sums over
the active slots.  ``kv_pos`` may be a single position (every slot at the
same depth) or a sequence of per-slot positions; KV-read traffic is linear
in the *sum* of slot positions.  ``batch=1`` is bit-identical to the
unbatched step.

**Precision plane**: a workload carries ``weight_bits`` / ``kv_bits``
(default 16 — the paper's fp16 assumption, ``BYTES``).  Weight-streaming
terms scale with ``weight_bits`` and KV-cache terms with ``kv_bits``, so
the Plane-A quantisation plane (``repro.quant``: int8 / packed-int4
weights, quantised slot-pool KV) propagates into what *bytes* move on the
fabric, not just when they move.  Quantised terms add the f32 scale
overhead the Plane-A layout actually stores (one scale per output channel
for weights, one per (token, head) KV row); at 16 bits every term is
bit-identical to the pre-quantisation model — the Table-4 calibration
contract is untouched.
"""
from __future__ import annotations

import dataclasses
import math
import numbers
from typing import Optional

from repro.config import ModelConfig

BYTES = 2  # fp16 *activation* operands (the paper's 16-bit assumption);
#            weight / KV-cache terms use Workload.weight_bits / kv_bits

SCALE_BYTES = 4  # f32 quantisation scales (repro.quant stores f32 planes)


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    seq_len: int
    enc_dec: bool = False
    parallel_mha_ff: bool = False        # GPT-J (paper eq. 9)
    n_enc_layers: int = 0                # encoder share of n_layers (enc-dec)
    weight_bits: int = 16                # streamed-weight precision (16 = fp)
    kv_bits: int = 16                    # KV-cache precision (16 = fp)

    def __post_init__(self):
        # direct construction with enc_dec=True but no declared encoder
        # share keeps the legacy symmetric-stack assumption rather than
        # silently treating every layer as a decoder layer
        if self.enc_dec and self.n_enc_layers == 0:
            object.__setattr__(self, "n_enc_layers", self.n_layers // 2)
        for bits in (self.weight_bits, self.kv_bits):
            if bits not in (4, 8, 16):
                raise ValueError(f"precision must be 4, 8 or 16 bits, got {bits}")

    @property
    def n_dec_layers(self) -> int:
        """Decoder-stack depth — the layers that run per generated token."""
        return self.n_layers - self.n_enc_layers

    @property
    def kv_frac(self) -> float:
        """K/V share vs MHA (GQA/MQA collapse the cached heads)."""
        return self.n_kv_heads / self.n_heads

    def weight_dram_bytes(self, k_dim: int, n_dim: int) -> float:
        """DRAM bytes to stream one (k_dim, n_dim) weight matrix at this
        workload's weight precision.  Quantised weights add the f32
        per-output-channel scale plane (``repro.quant`` layout); at 16 bits
        the term is bit-identical to ``k_dim * n_dim * BYTES``."""
        base = k_dim * n_dim * (self.weight_bits / 8)
        if self.weight_bits < 16:
            base += n_dim * SCALE_BYTES
        return base

    @classmethod
    def from_config(cls, cfg: ModelConfig, seq_len: int, *,
                    weight_bits: int = 16, kv_bits: int = 16) -> "Workload":
        return cls(
            name=cfg.name, d_model=cfg.d_model,
            n_layers=cfg.n_layers + cfg.n_encoder_layers,
            n_heads=max(cfg.n_heads, 1), n_kv_heads=max(cfg.n_kv_heads, 1),
            d_ff=cfg.d_ff or 4 * cfg.d_model, vocab=cfg.vocab_size,
            seq_len=seq_len, enc_dec=cfg.n_encoder_layers > 0,
            parallel_mha_ff=cfg.parallel_block,
            n_enc_layers=cfg.n_encoder_layers,
            weight_bits=weight_bits, kv_bits=kv_bits)


@dataclasses.dataclass
class Phase:
    """One execution phase with compute (by platform) and traffic terms."""
    name: str
    sm_flops: float = 0.0
    reram_flops: float = 0.0
    dram_bytes: float = 0.0          # DRAM→MC weight/act streaming
    sm_mc_bytes: float = 0.0         # many-to-few SM↔MC exchange
    reram_pipe_bytes: float = 0.0    # ReRAM_i→ReRAM_{i+1} (SFC pipeline)
    mc_reram_bytes: float = 0.0      # macro head/tail ↔ MC
    host_bytes: float = 0.0          # baseline host round-trips only
    dram_dram_bytes: float = 0.0     # DRAM→NoI→DRAM re-sharding (recovery
    #                                  KV migration off a failed chiplet —
    #                                  0 on every nominal workload phase)
    repeat: int = 1                  # executed per layer?


def transformer_phases(w: Workload) -> list[Phase]:
    N, D, F, h = w.seq_len, w.d_model, w.d_ff, w.n_heads
    hd = D // h
    kv_frac = w.n_kv_heads / w.n_heads

    phases = [Phase(
        "embed",
        reram_flops=2.0 * N * D,                       # MVM lookup+pos (eq. 1)
        reram_pipe_bytes=N * D * BYTES,
        mc_reram_bytes=N * D * BYTES,
    )]

    # ② load W_K,Q,V through MCs + ③ KQV compute on SMs (eqs 2-3)
    w_kqv = w.weight_dram_bytes(D, (1 + 2 * kv_frac) * D)  # MQA shrinks K/V
    kqv = Phase(
        "kqv",
        sm_flops=2.0 * N * D * D * (1 + 2 * kv_frac),
        dram_bytes=w_kqv + N * D * BYTES,
        sm_mc_bytes=N * D * (1 + 2 * kv_frac) * BYTES,
        repeat=w.n_layers,
    )
    # ④ score: QKᵀ + softmax + ·V + output proj (eqs 4-7), fused on SM
    score = Phase(
        "score",
        sm_flops=2.0 * N * N * D * 2 + 2.0 * N * D * D,
        sm_mc_bytes=2 * N * D * BYTES,
        dram_bytes=w.weight_dram_bytes(D, D),
        repeat=w.n_layers,
    )
    # ⑤ feed-forward on the ReRAM macro (two FC layers, weight-stationary)
    ff = Phase(
        "ff",
        reram_flops=2.0 * N * D * F * 2,
        mc_reram_bytes=2 * N * D * BYTES,
        reram_pipe_bytes=N * F * BYTES,
        repeat=w.n_layers,
    )
    phases += [kqv, score, ff]
    if w.enc_dec:
        # decoder cross-attention adds one extra attention block per
        # *decoder* layer — repeat follows the decoder stack, not half the
        # total (which was only correct for symmetric enc/dec stacks)
        cross = Phase(
            "cross",
            sm_flops=2.0 * N * N * D + 2.0 * N * D * D * (1 + 2 * kv_frac) / 2,
            sm_mc_bytes=2 * N * D * BYTES,
            dram_bytes=w.weight_dram_bytes(D, D),
            repeat=w.n_dec_layers,
        )
        phases.append(cross)
    phases.append(Phase("lm_head",
                        reram_flops=2.0 * N * D * w.vocab / max(N, 1),
                        mc_reram_bytes=D * w.vocab * BYTES / max(N, 1)))
    return phases


# ---------------------------------------------------------------------------
# generation: prefill (+KV write-back) and per-token decode phases
# ---------------------------------------------------------------------------

def kv_cache_bytes_per_layer(w: Workload, kv_len: int) -> float:
    """K + V cache rows for ``kv_len`` positions of one (decoder) layer —
    the quantity streamed DRAM→MC→SM at every decode step and written back
    during prefill.  GQA/MQA shrink it by ``kv_frac``; ``w.kv_bits``
    shrinks the element bytes (quantised rows add the per-(token, head)
    f32 scale the Plane-A pool stores; 16 bits is bit-identical to fp)."""
    base = 2.0 * kv_len * w.d_model * w.kv_frac * (w.kv_bits / 8)
    if w.kv_bits < 16:
        base += 2.0 * kv_len * w.n_kv_heads * SCALE_BYTES
    return base


def prefill_phases(w: Workload) -> list[Phase]:
    """Prompt-ingest phases of a generation episode: the single forward
    pass over ``w.seq_len`` prompt tokens **plus** the explicit KV-cache
    write-back (SM→MC→DRAM) that the fixed-length model omits.  For
    enc-dec workloads the written cache is the cross-KV projection of the
    encoder output (same N·D·kv_frac footprint per decoder layer).

    ``transformer_phases`` itself is untouched — it remains the Table-4
    calibration surface."""
    kv_bytes = kv_cache_bytes_per_layer(w, w.seq_len)
    return transformer_phases(w) + [Phase(
        "kv_write",
        sm_mc_bytes=kv_bytes,            # SM→MC hand-off of the fresh K/V
        dram_bytes=kv_bytes,             # MC→DRAM cache commit
        repeat=w.n_dec_layers,
    )]


def _decode_batch_positions(kv_pos, batch: int) -> list[int]:
    """Normalise ``decode_step_phases``'s (kv_pos, batch) arguments into the
    per-slot position list.  An int position replicates over the batch; a
    sequence gives each slot its own depth (its length must match
    ``batch`` unless batch is the default 1, which it then overrides)."""
    if isinstance(kv_pos, numbers.Number):   # incl. numpy scalars
        if batch < 1:
            raise ValueError(f"batch must be >= 1, got {batch}")
        return [int(kv_pos)] * batch
    positions = [int(p) for p in kv_pos]
    if not positions:
        raise ValueError("kv_pos sequence is empty")
    if batch not in (1, len(positions)):
        raise ValueError(f"batch={batch} != len(kv_pos)={len(positions)}")
    return positions


def decode_weight_stream_bytes(w: Workload) -> float:
    """DRAM weight-streaming bytes of one decode *step* — the component
    paid once per step regardless of batch (W_KQV + attention output
    projection per decoder layer, + the cross output projection for
    enc-dec stacks).  Everything else in the step scales per slot."""
    D = w.d_model
    per_layer = (w.weight_dram_bytes(D, (1 + 2 * w.kv_frac) * D)
                 + w.weight_dram_bytes(D, D))
    if w.enc_dec:
        per_layer += w.weight_dram_bytes(D, D)
    return per_layer * w.n_dec_layers


def decode_step_phases(w: Workload, kv_pos, batch: int = 1) -> list[Phase]:
    """One autoregressive decode step over ``batch`` active KV slots.

    N=1 per slot: weights are re-streamed per *step* (the memory-bound
    regime; the batch amortises them), the score phase reads each slot's
    whole cached K/V (linear in the sum of slot positions, GQA-aware),
    each slot's fresh K/V row is written back, and enc-dec stacks re-read
    the frozen cross-KV of the ``w.seq_len``-token source per slot.  All
    per-layer phases repeat over the decoder stack only.

    ``kv_pos`` is a single position (all slots at the same depth) or a
    sequence of per-slot positions.  ``batch=1`` reproduces the unbatched
    step bit-identically."""
    positions = _decode_batch_positions(kv_pos, batch)
    B, sum_pos = len(positions), sum(positions)
    D, F, k = w.d_model, w.d_ff, w.n_dec_layers
    kv_frac = w.kv_frac
    kv_read = kv_cache_bytes_per_layer(w, sum_pos)   # Σ per-slot cache reads
    kv_write = kv_cache_bytes_per_layer(w, 1)
    w_kqv = w.weight_dram_bytes(D, (1 + 2 * kv_frac) * D)  # once per step

    phases = [Phase(
        "embed_dec",                      # per-slot 1-token embedding lookup
        reram_flops=B * 2.0 * D,
        reram_pipe_bytes=B * D * BYTES,
        mc_reram_bytes=B * D * BYTES,
    )]
    phases.append(Phase(
        "kqv_dec",                        # per-slot projections + KV commit
        sm_flops=B * 2.0 * D * D * (1 + 2 * kv_frac),
        dram_bytes=w_kqv + B * D * BYTES + B * kv_write,
        sm_mc_bytes=B * D * (1 + 2 * kv_frac) * BYTES + B * kv_write,
        repeat=k,
    ))
    phases.append(Phase(
        "score_dec",                      # q·Kᵀ, softmax, ·V over each cache
        sm_flops=2.0 * sum_pos * D * 2 + B * 2.0 * D * D,
        dram_bytes=w.weight_dram_bytes(D, D) + kv_read,
        sm_mc_bytes=B * 2 * D * BYTES,
        repeat=k,
    ))
    if w.enc_dec:
        enc_kv = kv_cache_bytes_per_layer(w, w.seq_len)
        phases.append(Phase(
            "cross_dec",                  # attend over the frozen cross-KV
            sm_flops=B * (2.0 * w.seq_len * D * 2 + 2.0 * D * D),
            dram_bytes=w.weight_dram_bytes(D, D) + B * enc_kv,
            sm_mc_bytes=B * 2 * D * BYTES,
            repeat=k,
        ))
    phases.append(Phase(
        "ff_dec",                         # ReRAM-stationary: all per-slot
        reram_flops=B * 2.0 * D * F * 2,
        mc_reram_bytes=B * 2 * D * BYTES,
        reram_pipe_bytes=B * F * BYTES,
        repeat=k,
    ))
    phases.append(Phase(
        "lm_head_dec",                    # every generated token pays the head
        reram_flops=B * 2.0 * D * w.vocab,
        mc_reram_bytes=B * (D + w.vocab) * BYTES,
    ))
    return phases


# ---------------------------------------------------------------------------
# speculative decoding: k-token draft + verify steps (acceptance-amortised)
# ---------------------------------------------------------------------------

def spec_tokens_per_step(spec_k: int, acceptance: float) -> float:
    """Expected tokens committed per slot by one speculative step.

    With per-draft acceptance probability ``a`` the leading accepted run
    has length ``n`` with ``P(n >= j) = a^j``, so ``E[n] = sum a^j``; the
    verify pass always contributes one extra token (the correction /
    bonus token), hence ``E[committed] = 1 + sum_{j=1..k} a^j``.  At
    ``a=0`` this is 1 (plain decode cadence, every draft wasted); at
    ``a=1`` it is ``k+1``."""
    if spec_k < 0:
        raise ValueError(f"spec_k must be >= 0, got {spec_k}")
    if not 0.0 <= acceptance <= 1.0:
        raise ValueError(f"acceptance must be in [0, 1], got {acceptance}")
    return 1.0 + sum(acceptance ** j for j in range(1, spec_k + 1))


def spec_decode_step_phases(w: Workload, kv_pos, batch: int = 1, *,
                            spec_k: int, draft_w: Optional[Workload] = None,
                            ) -> list[Phase]:
    """One speculative decode step: ``spec_k`` draft decode steps plus a
    single ``(spec_k+1)``-token verify pass over ``batch`` KV slots.

    The draft passes are plain ``decode_step_phases`` executions of the
    draft workload (``draft_w`` — defaults to ``w`` itself, i.e.
    self-speculation at serving precision; pass
    ``dataclasses.replace(w, weight_bits=8)`` for a quantised self-draft
    or a small-model workload for draft-model speculation) at successive
    KV depths ``pos .. pos+spec_k-1``.

    The verify pass is where speculation beats plain decode: the target
    weight stream (W_KQV + output projection per decoder layer) is paid
    **once** while activations, KV-cache reads and KV row commits scale
    with the ``spec_k+1`` in-stream tokens per slot — so the
    bytes-per-committed-token falls as acceptance rises (divide this
    step's traffic by ``batch * spec_tokens_per_step(spec_k, a)``).
    Rejected rows are invalidated host-side (index writes, no fabric
    stream), so the verify commit traffic is the same whether drafts are
    accepted or not — acceptance only changes what the step *yields*.

    ``decode_step_phases`` and ``transformer_phases`` are untouched: at
    ``spec_k=0`` with no draft this returns exactly the plain step's
    phases (Table-4 / batch-1 calibration pins are preserved)."""
    if spec_k < 0:
        raise ValueError(f"spec_k must be >= 0, got {spec_k}")
    if w.enc_dec:
        raise ValueError("speculative decode models decoder-only stacks "
                         "(the serving engine's packable contract)")
    if spec_k == 0:
        return decode_step_phases(w, kv_pos, batch)
    positions = _decode_batch_positions(kv_pos, batch)
    B = len(positions)
    dw = draft_w if draft_w is not None else w
    phases: list[Phase] = []
    # -- draft: spec_k plain decode steps of the draft workload ----------
    for j in range(spec_k):
        dpos = [p + j for p in positions]
        for p in decode_step_phases(dw, dpos, B):
            phases.append(dataclasses.replace(p, name=f"draft{j}_{p.name}"))
    # -- verify: T in-stream tokens per slot, target weights once --------
    T = spec_k + 1
    sum_pos = sum(positions)
    D, F, k = w.d_model, w.d_ff, w.n_dec_layers
    kv_frac = w.kv_frac
    # row j of the in-stream block attends its slot's pos+j cached rows
    attend = T * sum_pos + B * T * (T - 1) // 2
    kv_read = kv_cache_bytes_per_layer(w, attend)
    kv_write = kv_cache_bytes_per_layer(w, T)        # T fresh rows per slot
    w_kqv = w.weight_dram_bytes(D, (1 + 2 * kv_frac) * D)  # once per step
    phases.append(Phase(
        "verify_embed",
        reram_flops=B * T * 2.0 * D,
        reram_pipe_bytes=B * T * D * BYTES,
        mc_reram_bytes=B * T * D * BYTES,
    ))
    phases.append(Phase(
        "verify_kqv",                     # weights once, T commits per slot
        sm_flops=B * T * 2.0 * D * D * (1 + 2 * kv_frac),
        dram_bytes=w_kqv + B * T * D * BYTES + B * kv_write,
        sm_mc_bytes=B * T * D * (1 + 2 * kv_frac) * BYTES + B * kv_write,
        repeat=k,
    ))
    phases.append(Phase(
        "verify_score",                   # each in-stream row reads the cache
        sm_flops=2.0 * attend * D * 2 + B * T * 2.0 * D * D,
        dram_bytes=w.weight_dram_bytes(D, D) + kv_read,
        sm_mc_bytes=B * T * 2 * D * BYTES,
        repeat=k,
    ))
    phases.append(Phase(
        "verify_ff",
        reram_flops=B * T * 2.0 * D * F * 2,
        mc_reram_bytes=B * T * 2 * D * BYTES,
        reram_pipe_bytes=B * T * F * BYTES,
        repeat=k,
    ))
    phases.append(Phase(
        "verify_lm_head",                 # logits at all T positions
        reram_flops=B * T * 2.0 * D * w.vocab,
        mc_reram_bytes=B * T * (D + w.vocab) * BYTES,
    ))
    return phases


# ---------------------------------------------------------------------------
# recovery: checkpoint write-back and KV-shard migration (crash safety)
# ---------------------------------------------------------------------------

def pool_kv_bytes_per_layer(w: Workload, kv_pos, batch: int = 1) -> float:
    """KV bytes one decoder layer holds for a ``batch``-slot pool at the
    given per-slot positions — the per-layer footprint a snapshot writes
    and a recovery re-materialises.  Linear in the *sum* of positions
    (``kv_cache_bytes_per_layer``), so it matches the decode-read
    accounting bit-for-bit."""
    positions = _decode_batch_positions(kv_pos, batch)
    return kv_cache_bytes_per_layer(w, sum(positions))


def checkpoint_phases(w: Workload, kv_pos, batch: int = 1, *,
                      every: int = 32) -> list[Phase]:
    """Per-decode-step amortised snapshot write-back stream.

    A crash-safe engine commits its full slot-pool state every ``every``
    iterations (``repro.serving.checkpoint``); between snapshots the
    write-back streams SM→MC→DRAM exactly like the prefill ``kv_write``
    commit, amortised to ``1/every`` of the pool per step.  Appended to
    *generation* phase lists only — ``transformer_phases`` (the Table-4
    calibration surface) never carries it."""
    if every <= 0:
        raise ValueError(f"checkpoint period must be positive, got {every}")
    b = pool_kv_bytes_per_layer(w, kv_pos, batch) / every
    return [Phase("ckpt_write",
                  sm_mc_bytes=b,           # SM→MC hand-off of the dirty rows
                  dram_bytes=b,            # MC→DRAM snapshot commit
                  repeat=w.n_dec_layers)]


def recovery_phases(w: Workload, kv_pos, batch: int = 1, *,
                    lost_frac: float = 0.0) -> list[Phase]:
    """One-time recovery traffic after a chiplet loss (the MTTR event).

    Two streams, both priced on the *degraded* fabric (pass the same
    ``scenario=`` to the NoI evaluation that models the failure):

    - ``kv_migrate`` — the KV shards orphaned on the failed chiplet
      (``lost_frac`` of the pool: dead DRAM members / DRAM role size)
      re-materialise from their checkpoint/replica holders onto the
      surviving DRAM chiplets, DRAM→NoI→DRAM over surviving links;
    - ``ckpt_restore`` — the engine revives from its last snapshot: the
      full pool state streams DRAM→MC→SM once so decode can resume.

    ``lost_frac=0`` (a non-DRAM chiplet died) still pays the restore
    read; nominal workloads never include these phases, so the Table-4
    calibration surface is untouched."""
    if not 0.0 <= lost_frac <= 1.0:
        raise ValueError(f"lost_frac must be in [0, 1], got {lost_frac}")
    pool = pool_kv_bytes_per_layer(w, kv_pos, batch)
    phases = []
    if lost_frac > 0.0:
        phases.append(Phase("kv_migrate",
                            dram_dram_bytes=pool * lost_frac,
                            repeat=w.n_dec_layers))
    phases.append(Phase("ckpt_restore",
                        dram_bytes=pool,     # DRAM→MC snapshot read
                        sm_mc_bytes=pool,    # MC→SM re-prime of the pool
                        repeat=w.n_dec_layers))
    return phases


def phase_bytes(ph: Phase) -> float:
    """Total bytes one execution of a phase injects into the fabric."""
    return (ph.dram_bytes + ph.sm_mc_bytes + ph.reram_pipe_bytes
            + ph.mc_reram_bytes + ph.host_bytes + ph.dram_dram_bytes)


def total_traffic_bytes(phases: list[Phase]) -> float:
    """Repeat-weighted bytes injected by a whole phase list."""
    return sum(phase_bytes(p) * p.repeat for p in phases)


def rewrites_per_token(w: Workload) -> float:
    """ReRAM cell rewrites per token if attention ran on PIM (§4.4).

    K/Q/V intermediates change every token: writing N×(3·D) fp16 operand
    matrices into 2-bit cells → bit-writes per cell per token."""
    bits_per_token = 3 * w.d_model * 16          # KQV row writes
    score_bits = 2 * w.seq_len * w.n_heads * 16  # score + prob rows
    return (bits_per_token + score_bits) * w.seq_len / 2  # per 2-bit cell


# ---------------------------------------------------------------------------
# chiplet-level traffic matrices
# ---------------------------------------------------------------------------

def phase_traffic_matrix(phase: Phase, roles: dict[str, list[int]],
                         n_chiplets: int):
    """Expand a Phase into F_ij bytes between chiplet ids.

    roles: {"SM": [ids], "MC": [ids], "DRAM": [ids], "ReRAM": [ids SFC-ordered],
            "HOST": [ids]} — placement-independent logical traffic; the NoI
    evaluator maps it onto links via routing.
    """
    F = {}

    def add(i, j, b):
        if b <= 0 or i == j:
            return
        F[(i, j)] = F.get((i, j), 0.0) + b

    sms, mcs = roles.get("SM", []), roles.get("MC", [])
    drams, rerams = roles.get("DRAM", []), roles.get("ReRAM", [])
    hosts = roles.get("HOST", [])

    # DRAM→MC (point-to-point pairs) then MC→SM fan-out (many-to-few)
    if phase.dram_bytes and mcs:
        per_mc = phase.dram_bytes / len(mcs)
        for mi, m in enumerate(mcs):
            d = drams[mi % len(drams)] if drams else m
            add(d, m, per_mc)
        if sms:
            per_sm = phase.dram_bytes / len(sms)
            for s in sms:
                m = mcs[hash(s) % len(mcs)]
                add(m, s, per_sm)

    if phase.sm_mc_bytes and sms and mcs:
        per_sm = phase.sm_mc_bytes / len(sms)
        for si, s in enumerate(sms):
            m = mcs[si % len(mcs)]
            add(s, m, per_sm / 2)
            add(m, s, per_sm / 2)

    if phase.reram_pipe_bytes and len(rerams) > 1:
        # spatially-partitioned pipeline: the weights are sliced across the
        # macro, so each SFC hop carries only that stage's activation slice
        per_hop = phase.reram_pipe_bytes / len(rerams)
        for a, b in zip(rerams[:-1], rerams[1:]):
            add(a, b, per_hop)

    if phase.mc_reram_bytes and rerams and mcs:
        head, tail = rerams[0], rerams[-1]
        m = mcs[0]
        add(m, head, phase.mc_reram_bytes / 2)
        add(tail, m, phase.mc_reram_bytes / 2)

    if phase.dram_dram_bytes and len(drams) > 1:
        # recovery re-sharding: orphaned KV shards re-materialise across
        # the surviving DRAM chiplets (ring neighbours — each survivor
        # receives its share from the replica/checkpoint holder next to
        # it).  With one DRAM chiplet there is no inter-chiplet hop.
        per_hop = phase.dram_dram_bytes / len(drams)
        for di, d in enumerate(drams):
            add(d, drams[(di + 1) % len(drams)], per_hop)

    if phase.host_bytes and hosts:
        # host round trips (baselines): every SM/ReRAM talks to host
        src = sms or rerams
        per = phase.host_bytes / max(len(src), 1)
        for s in src:
            add(s, hosts[0], per / 2)
            add(hosts[0], s, per / 2)
    return F
