"""Request-level streaming front end over the serving engine.

The engine exposes batch mechanics (submit / step / drain); this layer
exposes *requests*: :meth:`ServingFrontend.submit` returns a
:class:`TokenStream` whose tokens can be consumed incrementally — by
iterating it (the iterator cooperatively pumps the engine until the
next token lands) or via an ``on_token`` callback fired as each token
is produced.  :meth:`ServingFrontend.play` replays a workload
(``serving/workload.py`` arrivals) against the engine clock: requests
are submitted when due and the engine pumps between arrivals, which is
how the capacity benchmark offers open-loop load.

Cooperative, not threaded: the engine mutates device state and host
bookkeeping with no locking, so all progress happens on the caller's
thread inside :meth:`pump` — one engine iteration plus delivery of any
new tokens to their streams.  Iterating a stream, draining, and playing
a workload are all loops over ``pump()``; callbacks fire synchronously
in submission order.  When a checkpointer is attached, submits route
through its journal so the crash-safety contract
(``serving/checkpoint.py``) covers streamed traffic too.
"""
from __future__ import annotations

import time
from typing import Callable, Iterator, Optional, Sequence

from repro.serving.engine import EngineStallError, Request, ServingEngine

OnToken = Callable[["TokenStream", int], None]


class TokenStream:
    """Handle on one streamed request: buffered tokens + liveness.

    ``for tok in stream`` yields every generated token, pumping the
    engine while the next token is still in flight.  ``tokens`` is the
    list delivered so far, ``status``/``done`` mirror the underlying
    :class:`Request` terminal state (a rejected or failed request just
    ends its stream early — the status says why)."""

    def __init__(self, frontend: "ServingFrontend", request: Request,
                 on_token: Optional[OnToken] = None):
        self._frontend = frontend
        self.request = request
        self.on_token = on_token
        self.tokens: list[int] = []

    @property
    def uid(self) -> int:
        return self.request.uid

    @property
    def status(self) -> str:
        return self.request.status

    @property
    def done(self) -> bool:
        return self.request.terminal

    def __iter__(self) -> Iterator[int]:
        idx = 0
        while True:
            while idx < len(self.tokens):
                yield self.tokens[idx]
                idx += 1
            if self.done and idx >= len(self.tokens):
                return
            self._frontend.pump()

    # -- frontend-internal ---------------------------------------------------
    def _deliver(self) -> None:
        """Forward tokens the engine has committed since last delivery."""
        out = self.request.output
        while len(self.tokens) < len(out):
            tok = out[len(self.tokens)]
            self.tokens.append(tok)
            if self.on_token is not None:
                self.on_token(self, tok)


class ServingFrontend:
    def __init__(self, engine: ServingEngine, *, checkpointer=None,
                 sleep: Callable[[float], None] = time.sleep):
        self.engine = engine
        self.checkpointer = checkpointer
        self._sleep = sleep
        self.streams: list[TokenStream] = []
        self._live: list[TokenStream] = []

    # -- submission ----------------------------------------------------------
    def submit(self, prompt, max_new_tokens: Optional[int] = None, *,
               priority: int = 0,
               on_token: Optional[OnToken] = None) -> TokenStream:
        """Enqueue one request and return its stream.  Routed through the
        attached checkpointer's journal when one is present."""
        if self.checkpointer is not None:
            req = self.checkpointer.submit(prompt, max_new_tokens,
                                           priority=priority)
        else:
            req = self.engine.submit(prompt, max_new_tokens,
                                     priority=priority)
        stream = TokenStream(self, req, on_token)
        self.streams.append(stream)
        if not req.terminal:             # REJECTED never enters the engine
            self._live.append(stream)
        return stream

    # -- progress ------------------------------------------------------------
    def idle(self) -> bool:
        """No queued and no in-slot work — pump() would be a no-op."""
        return not self.engine.queue and self.engine.pool.occupied() == 0

    def pump(self) -> int:
        """One engine iteration + delivery of every newly committed token
        to its stream (callbacks fire here, in submission order).
        Returns the number of occupied slots."""
        occupied = self.engine.step()
        if self.checkpointer is not None:
            self.checkpointer.maybe_save()
        still = []
        for stream in self._live:
            stream._deliver()
            if not stream.done:
                still.append(stream)
        self._live = still
        return occupied

    def drain(self, max_iters: int = 10_000) -> list[TokenStream]:
        """Pump until every submitted stream is terminal."""
        it = 0
        while not self.idle():
            self.pump()
            it += 1
            if it > max_iters:
                raise EngineStallError(
                    f"frontend did not drain in {max_iters} iterations")
        for stream in self._live:        # failed/evicted without a step
            stream._deliver()
        self._live = []
        return self.streams

    # -- workload replay -----------------------------------------------------
    def play(self, arrivals: Sequence, *,
             max_iters: int = 1_000_000) -> list[TokenStream]:
        """Offer a workload open-loop: each arrival is submitted when the
        engine clock reaches its due time (``Arrival.t``, relative to
        play start), the engine pumps whenever work is in flight, and
        the pool sleeps through genuinely idle gaps.  Returns every
        stream after a full drain.  The clock is
        ``EngineConfig.clock`` and the sleeper is injectable, so tests
        replay workloads on a fake clock with no real waiting."""
        clock = self.engine.ecfg.clock
        t0 = clock()
        order = sorted(range(len(arrivals)), key=lambda i: arrivals[i].t)
        streams = []
        i = 0
        it = 0
        while i < len(order) or not self.idle():
            now = clock() - t0
            while i < len(order) and arrivals[order[i]].t <= now:
                a = arrivals[order[i]]
                streams.append(self.submit(a.prompt, a.max_new_tokens,
                                           priority=a.priority))
                i += 1
            if i < len(order) and self.idle():
                # nothing in flight: jump to the next arrival
                self._sleep(max(arrivals[order[i]].t - (clock() - t0), 0.0))
            elif not self.idle():
                self.pump()
            it += 1
            if it > max_iters:
                raise EngineStallError(
                    f"workload did not complete in {max_iters} iterations")
        return streams
