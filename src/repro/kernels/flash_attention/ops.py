"""jit'd dispatch wrapper for attention.

``impl``:
  - ``ref``               pure-jnp chunked oracle (CPU, dry-run HLO)
  - ``pallas``            TPU Pallas kernels (compiled)
  - ``pallas_interpret``  Pallas kernel bodies executed in Python on CPU
  - ``flash``             serving fast path: Pallas kernels, compiled on TPU
                          and interpreted elsewhere (CPU tests exercise the
                          real kernel bodies)
  - ``auto``              pallas on TPU backends, ref elsewhere

Two Pallas kernels sit behind this wrapper:

- :func:`..kernel.flash_attention_fwd` — train/prefill self-attention with
  implicit positions (long query blocks).  With ``segments=`` it runs the
  **ragged/packed** variant: several prompts in one token stream, per-token
  prompt ids (-1 = pad), no cross-prompt attention;
- :func:`..decode.flash_decode_fwd`    — the decode fast path: ``Sq == 1``
  with explicit ``q_pos``/``kv_pos`` vectors (slotted / ring-buffer caches,
  per-slot lengths, empty-slot masking).

The decode kernel treats ``kv_pos < 0`` as invalid; an explicit ``kv_valid``
mask is folded into ``kv_pos`` before the call (masked entries become -1),
so any caller-supplied mask is honoured exactly.  Non-causal decode with
explicit positions (cross-attention) is expressed by callers as causal
attention with ``q_pos >= max(kv_pos)`` — see ``models/attention.py``.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.common import blocks_aligned
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_attention import kernel as _kernel
from repro.kernels.flash_attention import decode as _decode


def _pallas_ok(q, k, causal, q_pos, kv_pos, kv_valid, window, segments):
    if q_pos is not None or kv_pos is not None or kv_valid is not None:
        return False
    B, Sq, Hq, hd = q.shape
    Skv = k.shape[1]
    if Sq < 8 or Skv < 8:
        return False
    if segments is not None and Sq != Skv:
        return False
    return (blocks_aligned(Sq, 128) and blocks_aligned(Skv, 128)
            and Hq % k.shape[2] == 0)


def _decode_ok(q, k, causal, q_pos, kv_pos):
    if not causal or q_pos is None or kv_pos is None:
        return False
    B, Sq, Hq, hd = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    if Sq != 1 or Hq % Hkv:
        return False
    return blocks_aligned(Skv, 128)


def attention(
    q: jax.Array,            # (B, Sq, Hq, hd)
    k: jax.Array,            # (B, Skv, Hkv, hd)   (int8 codes when k_scale=)
    v: jax.Array,            # (B, Skv, Hkv, hdv)
    *,
    q_pos: Optional[jax.Array] = None,
    kv_pos: Optional[jax.Array] = None,
    kv_valid: Optional[jax.Array] = None,
    segments: Optional[jax.Array] = None,   # (B, S) packed prompt ids, -1 pad
    k_scale: Optional[jax.Array] = None,    # (B, Skv, Hkv) quantised-KV scales
    v_scale: Optional[jax.Array] = None,
    kv_bits: int = 0,                       # 8 | 4 when k_scale/v_scale given
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: Optional[float] = None,
    impl: str = "auto",
) -> jax.Array:
    """``k_scale``/``v_scale`` switch K/V to the quantised-KV convention:
    ``k``/``v`` carry int8 codes (packed two-per-byte along the head dim for
    ``kv_bits=4``) with per-(entry, head) scales.  The decode-shaped Pallas
    route runs :func:`..decode.flash_decode_quant_fwd` (in-VMEM dequant);
    every other route dequantises up front and proceeds as fp."""
    if impl not in ("ref", "auto", "flash", "pallas", "pallas_interpret"):
        raise ValueError(f"unknown attention impl {impl!r}")
    on_tpu = jax.default_backend() == "tpu"
    if impl == "auto":
        impl = "pallas" if on_tpu else "ref"
    if impl == "flash":
        impl = "pallas" if on_tpu else "pallas_interpret"

    if k_scale is not None:
        if kv_bits not in (4, 8):
            raise ValueError(f"quantised KV needs kv_bits 4 or 8, got {kv_bits}")
        if impl in ("pallas", "pallas_interpret") and \
                _decode_ok(q, k, causal, q_pos, kv_pos):
            kp = kv_pos if kv_valid is None else jnp.where(kv_valid, kv_pos, -1)
            return _decode.flash_decode_quant_fwd(
                q, k, k_scale, v, v_scale, kv_bits=kv_bits, q_pos=q_pos,
                kv_pos=kp, window=window, softcap=softcap, scale=scale,
                interpret=impl == "pallas_interpret")
        from repro.quant.core import dequantize_kv
        k = dequantize_kv(k, k_scale, kv_bits).astype(q.dtype)
        v = dequantize_kv(v, v_scale, kv_bits).astype(q.dtype)

    if impl in ("pallas", "pallas_interpret"):
        interpret = impl == "pallas_interpret"
        if _decode_ok(q, k, causal, q_pos, kv_pos):
            kp = kv_pos if kv_valid is None else jnp.where(kv_valid, kv_pos, -1)
            return _decode.flash_decode_fwd(
                q, k, v, q_pos=q_pos, kv_pos=kp, window=window,
                softcap=softcap, scale=scale, interpret=interpret)
        if _pallas_ok(q, k, causal, q_pos, kv_pos, kv_valid, window, segments):
            qt = q.transpose(0, 2, 1, 3)   # (B, H, S, hd)
            kt = k.transpose(0, 2, 1, 3)
            vt = v.transpose(0, 2, 1, 3)
            out = _kernel.flash_attention_fwd(
                qt, kt, vt, segments=segments, causal=causal, window=window,
                softcap=softcap, scale=scale, interpret=interpret)
            return out.transpose(0, 2, 1, 3)

    return attention_ref(
        q, k, v, q_pos=q_pos, kv_pos=kv_pos, kv_valid=kv_valid,
        q_seg=segments, kv_seg=segments,
        causal=causal, window=window, softcap=softcap, scale=scale)
