"""End-to-end quantization plane: int8 / packed-int4 weights, quantised KV
caches, and the fused dequant compute kernels (see ``quant/core.py``)."""
from repro.quant.core import (  # noqa: F401
    KV_BITS, QMAX, QUANT_PARAM_KEYS, WEIGHT_BITS, XBAR, QuantTensor,
    dequantize, dequantize_kv, fake_quantize_params, kv_cache_bits,
    pack_int4, quantize, quantize_kv, quantize_kv_cache, quantize_params,
    quantize_weights, unpack_int4)
from repro.quant.ops import qdense, quant_matmul  # noqa: F401
