"""§3.1 dataflow microbenchmarks: the two Pallas kernels vs their jnp
oracles — correctness (interpret mode) + CPU wall-clock of the oracle path
(the compiled-TPU numbers come from the dry-run roofline instead)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.pim_mvm.ops import pim_mvm, quantize_weights
from repro.kernels.pim_mvm.ref import pim_mvm_ref

from benchmarks.common import emit, timed


def run(verbose: bool = True) -> list[dict]:
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention sweep
    for (B, S, Hq, Hkv, hd) in ((1, 256, 8, 8, 64), (2, 512, 8, 2, 64),
                                (1, 1024, 4, 1, 128)):
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, S, Hq, hd), jnp.float32)
        k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.float32)
        v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.float32)
        out = attention(q, k, v, causal=True, impl="pallas_interpret")
        ref, us = timed(jax.jit(lambda a, b, c: attention_ref(a, b, c, causal=True)),
                        q, k, v)
        err = float(jnp.abs(out - ref).max())
        rows.append({"kernel": "flash_attention", "shape": f"{B}x{S}x{Hq}x{hd}",
                     "max_err_vs_ref": err, "ref_us": us,
                     "quant_rel_err": 0.0})
        assert err < 5e-5

    # pim mvm sweep
    for (M, K, N) in ((256, 1024, 512), (512, 2048, 1024)):
        ks = jax.random.split(key, 2)
        x = jax.random.normal(ks[0], (M, K), jnp.float32)
        wfp = jax.random.normal(ks[1], (K, N), jnp.float32)
        wq, s = quantize_weights(wfp)
        out = pim_mvm(x, wq, s, impl="pallas_interpret")
        ref, us = timed(jax.jit(pim_mvm_ref), x, wq, s)
        err = float(jnp.abs(out - ref).max())
        rel = float(jnp.abs(pim_mvm_ref(x, wq, s) - x @ wfp).max()
                    / jnp.abs(x @ wfp).max())
        rows.append({"kernel": "pim_mvm", "shape": f"{M}x{K}x{N}",
                     "max_err_vs_ref": err, "ref_us": us,
                     "quant_rel_err": rel})
        assert err < 5e-3 and rel < 0.02

    if verbose:
        emit(rows, "kernel_micro: Pallas vs oracle")
    return rows


if __name__ == "__main__":
    run()
