"""Serving decode fast-path benchmark: seed (host-looped) vs fused engine.

Measures steady-state decode throughput and device→host traffic per token
for the three serving configurations:

- ``seed``        — ``fused=False``: the original per-token host round trip
                    (host sampling fetch, Python slot loop, non-donated
                    cache → XLA copies the whole KV pool every token);
- ``fused``       — zero-host-sync jitted step with cache donation, one
                    packed ``(2, B)`` transfer per iteration, ref attention;
- ``fused_flash`` — same, routed through the Pallas decode-attention kernel
                    (interpret mode off-TPU, compiled on TPU).

Methodology: one warm-up drain performs every compile (prompts share one
length, so one prefill bucket), then the reported numbers are the best of
``repeat`` timed drains of the full serving loop — decode steps *plus*
continuous-batching admissions, measured identically for every path, so
the seed/fused comparison is apples-to-apples engine throughput.
Results go to ``experiments/BENCH_serving.json`` and are rendered by
``benchmarks/report.py``.

    PYTHONPATH=src python -m benchmarks.perf_serving [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "experiments")


def _tokens(eng) -> int:
    live = [r for r in eng.slot_req if r is not None]
    return sum(len(r.output) for r in list(eng.finished) + live)


def run_engine(cfg, params, *, fused: bool, impl: str, max_batch: int,
               kv_len: int, max_new_tokens: int, prompt_len: int,
               requests: int, decode_chunk: int = 1, repeat: int = 3) -> dict:
    import numpy as np
    from repro.serving.engine import EngineConfig, ServingEngine

    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=max_batch, kv_len=kv_len, max_new_tokens=max_new_tokens,
        impl=impl, fused=fused, decode_chunk=decode_chunk))
    rng = np.random.default_rng(0)

    def drain():
        for _ in range(requests):
            eng.submit(rng.integers(0, cfg.vocab_size, size=prompt_len))
        tok0, byte0, step0 = _tokens(eng), eng.host_bytes, eng.decode_steps
        t0 = time.perf_counter()
        eng.run_until_drained()
        dt = time.perf_counter() - t0
        return (_tokens(eng) - tok0, eng.decode_steps - step0,
                eng.host_bytes - byte0, dt)

    drain()                        # warm-up: all compiles happen here
    best = None
    for _ in range(repeat):        # repeated timed drains, keep the best
        toks, steps, bytes_, dt = drain()
        if best is None or toks / dt > best[0] / best[3]:
            best = (toks, steps, bytes_, dt)
    toks, steps, bytes_, dt = best
    return {
        "fused": fused,
        "impl": impl,
        "decode_chunk": decode_chunk,
        "tokens": toks,
        "decode_steps": steps,
        "tokens_per_s": toks / max(dt, 1e-9),
        "step_ms": dt / max(steps, 1) * 1e3,
        "host_bytes_per_token": bytes_ / max(toks, 1),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (seconds, still writes JSON)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--kv-len", type=int, default=64)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--decode-chunk", type=int, default=16,
                    help="device iterations per host sync on the fused path")
    ap.add_argument("--out", default=os.path.join(EXPERIMENTS,
                                                  "BENCH_serving.json"))
    args = ap.parse_args()
    if args.smoke:
        args.max_batch, args.kv_len = 2, 64
        args.max_new_tokens, args.prompt_len = 8, 8
        args.requests = 3

    import jax
    import jax.numpy as jnp
    from benchmarks.common import emit
    from repro.config import get_config, reduce_config

    from repro.models import transformer as T

    cfg = reduce_config(get_config(args.arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.bfloat16)

    shape = dict(max_batch=args.max_batch, kv_len=args.kv_len,
                 max_new_tokens=args.max_new_tokens,
                 prompt_len=args.prompt_len, requests=args.requests)
    results = {
        "seed": run_engine(cfg, params, fused=False, impl="ref", **shape),
        "fused": run_engine(cfg, params, fused=True, impl="ref",
                            decode_chunk=args.decode_chunk, **shape),
        "fused_flash": run_engine(cfg, params, fused=True, impl="flash",
                                  decode_chunk=args.decode_chunk, **shape),
    }
    rec = {
        "bench": "serving_decode",
        "arch": args.arch,
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        **shape,
        "results": results,
        "speedup_fused_vs_seed": (results["fused"]["tokens_per_s"]
                                  / max(results["seed"]["tokens_per_s"],
                                        1e-9)),
    }
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)

    rows = [{"path": k, **v} for k, v in results.items()]
    emit(rows, "serving_decode")
    print(f"speedup fused/seed: {rec['speedup_fused_vs_seed']:.2f}x "
          f"-> {args.out}")


if __name__ == "__main__":
    main()
