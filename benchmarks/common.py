"""Shared helpers for the paper-figure benchmarks."""
from __future__ import annotations

import time


def timed(fn, *args, repeat: int = 3, **kw):
    """(result, us_per_call) — median of ``repeat`` runs."""
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


def emit(rows: list[dict], name: str):
    """Print a labelled CSV block (consumed by benchmarks.run + EXPERIMENTS)."""
    if not rows:
        print(f"# {name}: no rows")
        return
    cols = list(rows[0].keys())
    print(f"# --- {name} ---")
    print(",".join(cols))
    for r in rows:
        print(",".join(_fmt(r[c]) for c in cols))


def _fmt(v):
    if isinstance(v, float):
        return f"{v:.6g}"
    return str(v)
