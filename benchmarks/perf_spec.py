"""Speculative-decoding benchmark: Plane-A k-token draft+verify serving
throughput and acceptance accounting, plus the Plane-B acceptance sweep
and the NoI question speculation raises — does the optimal fabric change
when decode arithmetic intensity rises?

Variants (fused ``ServingEngine`` on the reduced config, greedy decode
over identical prompt sets):

- ``baseline``  — ``spec_k=0``: plain one-token decode (the PR 8 engine);
- ``spec8_k4``  — self-speculation, ``spec_k=4``, int8 self-draft;
- ``spec4_k4``  — self-speculation, ``spec_k=4``, int4 self-draft (cheaper
                  drafts, lower acceptance);
- ``spec8_k2``  — shallower draft run (``spec_k=2``, int8).

Greedy speculative decoding is **lossless by construction** — accepted
drafts equal the target argmax and the bonus token *is* the target argmax
— so every variant's token streams must match the baseline exactly
(``exact_parity == 1.0`` is schema-gated, not a soft metric).  The win is
cadence: ``spec_tokens_per_step`` (tokens committed per slot per target
weight stream) must exceed 1 for the int8 draft, i.e. one weight stream
now buys more than one token.

The Plane-B section sweeps the acceptance-parameterised traffic model
(``spec_decode_step_phases``): fabric bytes per *committed* token must
fall monotonically in the acceptance rate (schema-gated), crossing below
the plain-decode line once the draft run amortises the verify overhead.
The NoI section replays the measured baseline and speculative mixes
through ``optimize_generation_noi`` at identical search budgets — same
recipe as every other NoI comparison — and reports both Pareto fronts so
the fabric question is answered on measured, not assumed, acceptance.

    PYTHONPATH=src python -m benchmarks.perf_spec [--smoke]

Results: ``experiments/BENCH_spec.json`` (``BENCH_spec_smoke.json`` with
``--smoke`` so CI never clobbers the recorded full run); rendered by
``benchmarks/report.py``.
"""
from __future__ import annotations

import argparse
import json
import os

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..", "experiments")

# name -> (spec_k, spec_draft_bits); spec_k=0 is the non-speculative pin
VARIANTS = {
    "baseline": (0, 0),
    "spec8_k4": (4, 8),
    "spec4_k4": (4, 4),
    "spec8_k2": (2, 8),
}

_VARIANT_KEYS = {"spec_k", "spec_draft_bits", "tokens", "tokens_per_s",
                 "decode_steps", "exact_parity", "prefix_parity",
                 "spec_acceptance", "spec_tokens_per_step"}
_SWEEP_KEYS = {"acceptance", "tokens_per_step", "step_gb", "gb_per_token",
               "reduction_vs_plain"}
_NOI_KEYS = {"spec_k", "spec_acceptance", "spec_tokens_per_step",
             "fabric_gb_per_token", "front", "best_mu"}


def check_schema(rec: dict) -> None:
    """Assert the BENCH_spec.json record shape (CI bit-rot gate)."""
    for key in ("bench", "arch", "backend", "smoke", "results",
                "planeb_sweep", "noi"):
        assert key in rec, f"missing top-level key {key!r}"
    for name in VARIANTS:
        row = rec["results"][name]
        missing = _VARIANT_KEYS - set(row)
        assert not missing, f"variant {name!r} missing {missing}"
        # greedy speculation is lossless: accepted drafts and the bonus
        # token are the target argmax — any mismatch is an engine bug
        assert row["exact_parity"] == 1.0, \
            f"variant {name!r} diverged from the baseline greedy stream"
    spec8 = rec["results"]["spec8_k4"]
    assert spec8["spec_tokens_per_step"] is not None \
        and spec8["spec_tokens_per_step"] > 1.0, \
        "int8 self-draft must commit >1 token per target weight stream"
    sweep = rec["planeb_sweep"]
    assert len(sweep) >= 3, "acceptance sweep needs >= 3 points"
    for row in sweep:
        missing = _SWEEP_KEYS - set(row)
        assert not missing, f"sweep row missing {missing}"
    gbs = [row["gb_per_token"] for row in sweep]
    assert all(a > b for a, b in zip(gbs, gbs[1:])), \
        "fabric bytes per committed token must fall monotonically in " \
        f"acceptance, got {gbs}"
    for name in ("baseline", "spec8_k4"):
        row = rec["noi"][name]
        missing = _NOI_KEYS - set(row)
        assert not missing, f"noi {name!r} missing {missing}"
        assert row["front"], f"noi {name!r} archive is empty"


def _prompts(cfg, requests: int, prompt_len: int):
    import numpy as np

    rng = np.random.default_rng(0)
    return [rng.integers(0, cfg.vocab_size, size=prompt_len)
            for _ in range(requests)]


def _drain(cfg, params, prompts, *, spec_k: int, spec_draft_bits: int,
           impl: str, max_batch: int, kv_len: int, max_new_tokens: int,
           repeat: int = 3):
    """Drain the prompt set; returns (outputs, stats, best timing)."""
    from repro.serving.engine import EngineConfig, ServingEngine

    from benchmarks.common import drain_best

    eng = ServingEngine(cfg, params, EngineConfig(
        max_batch=max_batch, kv_len=kv_len, max_new_tokens=max_new_tokens,
        impl=impl, spec_k=spec_k, spec_draft="self",
        spec_draft_bits=spec_draft_bits))

    def once():
        n0, s0 = len(eng.finished), eng.decode_steps
        for p in prompts:
            eng.submit(p)
        eng.run_until_drained()
        done = sorted(eng.finished[n0:], key=lambda r: r.uid)
        toks = sum(len(r.output) for r in done)
        return [tuple(r.output) for r in done], toks, eng.decode_steps - s0

    # warm-up drain (compiles + the parity record) + best-of-repeat —
    # the shared serving-benchmark methodology (benchmarks.common)
    warm, (_, toks, steps), dt, _ = drain_best(
        once, repeat=repeat, score=lambda r, dt: r[1] / dt)
    return warm[0], eng.stats(), (toks, steps, dt)


def _parity(ref, out) -> tuple[float, float]:
    import numpy as np

    exact = float(np.mean([a == b for a, b in zip(ref, out)]))
    prefix = float(np.mean([
        sum(x == y for x, y in zip(a, b)) / max(len(a), 1)
        for a, b in zip(ref, out)]))
    return exact, prefix


def acceptance_sweep(arch: str, prompt_len: int, batch: int, *,
                     spec_k: int, draft_bits: int) -> list[dict]:
    """Full-size Plane-B sweep: fabric bytes per committed token of one
    speculative step as the per-draft acceptance rate rises.  The step's
    traffic is acceptance-independent (rejected rows are invalidated
    host-side); acceptance only scales what the step yields — so the
    per-token curve is ``step_bytes / (batch * E[tokens])``."""
    import dataclasses

    from repro.config import get_config
    from repro.core.traffic import (Workload, decode_step_phases,
                                    spec_decode_step_phases,
                                    spec_tokens_per_step,
                                    total_traffic_bytes)

    w = Workload.from_config(get_config(arch), seq_len=prompt_len)
    draft_w = (dataclasses.replace(w, weight_bits=draft_bits)
               if draft_bits in (4, 8) else w)
    step_b = total_traffic_bytes(spec_decode_step_phases(
        w, prompt_len, batch, spec_k=spec_k, draft_w=draft_w))
    plain_b = total_traffic_bytes(decode_step_phases(w, prompt_len, batch))
    rows = []
    for acc in (0.0, 0.25, 0.5, 0.75, 0.9, 1.0):
        e = spec_tokens_per_step(spec_k, acc)
        per_tok = step_b / (batch * e)
        rows.append({
            "acceptance": acc,
            "tokens_per_step": e,
            "step_gb": step_b / 2**30,
            "gb_per_token": per_tok / 2**30,
            "reduction_vs_plain": (plain_b / batch) / per_tok,
        })
    return rows


def noi_comparison(arch: str, stats_by_variant: dict, chiplets: int, *,
                   iterations: int, ls_steps: int) -> dict:
    """Replay the measured baseline and speculative mixes through the one
    seeded NoI search recipe and report both Pareto fronts — the 'does
    the optimal fabric change' answer at identical search budgets."""
    from repro.config import get_config
    from repro.core.cosim import (generation_phases, mix_from_stats,
                                  optimize_generation_noi)
    from repro.core.traffic import total_traffic_bytes

    cfg = get_config(arch)
    out = {}
    for name in ("baseline", "spec8_k4"):
        mix = mix_from_stats(stats_by_variant[name])
        phases = generation_phases(cfg, mix)
        toks = max(mix.prefill_tokens + mix.decode_tokens, 1)
        res, _ = optimize_generation_noi(cfg, mix, chiplets,
                                         iterations=iterations,
                                         ls_steps=ls_steps, seed=0)
        front = sorted((float(f[0]), float(f[1]))
                       for f in res.archive.objs)
        out[name] = {
            "spec_k": mix.spec_k,
            "spec_acceptance": mix.spec_acceptance,
            "spec_tokens_per_step": mix.expected_tokens_per_step,
            "fabric_gb_per_token": total_traffic_bytes(phases) / toks / 2**30,
            "front": [list(f) for f in front],
            "best_mu": front[0][0] if front else None,
        }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (seconds, still writes JSON)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--kv-len", type=int, default=96)
    ap.add_argument("--max-new-tokens", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--impl", default="ref",
                    help="attention impl for the drains (flash = Pallas)")
    ap.add_argument("--chiplets", type=int, default=64)
    ap.add_argument("--planeb-prompt-len", type=int, default=512)
    ap.add_argument("--planeb-batch", type=int, default=8)
    ap.add_argument("--noi-iterations", type=int, default=3)
    ap.add_argument("--noi-ls-steps", type=int, default=12)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        args.out = os.path.join(
            EXPERIMENTS,
            "BENCH_spec_smoke.json" if args.smoke else "BENCH_spec.json")
    if args.smoke:
        args.max_batch, args.kv_len = 2, 64
        args.max_new_tokens, args.prompt_len, args.requests = 6, 8, 3
        args.planeb_prompt_len, args.planeb_batch = 64, 4
        args.noi_iterations, args.noi_ls_steps = 1, 4

    import jax
    import jax.numpy as jnp

    from benchmarks.common import emit
    from repro.config import get_config, reduce_config
    from repro.models import transformer as T

    cfg = reduce_config(get_config(args.arch))
    params = T.init_params(cfg, jax.random.PRNGKey(0),
                           param_dtype=jnp.float32)
    prompts = _prompts(cfg, args.requests, args.prompt_len)
    shape = dict(impl=args.impl, max_batch=args.max_batch,
                 kv_len=args.kv_len, max_new_tokens=args.max_new_tokens,
                 repeat=2 if args.smoke else 3)

    results, stats_by_variant = {}, {}
    base_out = None
    for name, (k, bits) in VARIANTS.items():
        out, stats, (toks, steps, dt) = _drain(
            cfg, params, prompts, spec_k=k, spec_draft_bits=bits, **shape)
        base_out = out if name == "baseline" else base_out
        exact, prefix = _parity(base_out, out)
        stats_by_variant[name] = stats
        results[name] = {
            "spec_k": k, "spec_draft_bits": bits, "tokens": toks,
            "tokens_per_s": toks / max(dt, 1e-9),
            "decode_steps": steps,
            "exact_parity": exact, "prefix_parity": prefix,
            "spec_acceptance": stats.get("spec_acceptance"),
            "spec_tokens_per_step": stats.get("spec_tokens_per_step"),
        }

    rec = {
        "bench": "spec",
        "arch": args.arch,
        "backend": jax.default_backend(),
        "smoke": bool(args.smoke),
        "impl": args.impl,
        "max_batch": args.max_batch, "kv_len": args.kv_len,
        "max_new_tokens": args.max_new_tokens,
        "prompt_len": args.prompt_len, "requests": args.requests,
        "results": results,
        "planeb_sweep": acceptance_sweep(args.arch, args.planeb_prompt_len,
                                         args.planeb_batch, spec_k=4,
                                         draft_bits=8),
        "noi": noi_comparison(args.arch, stats_by_variant, args.chiplets,
                              iterations=args.noi_iterations,
                              ls_steps=args.noi_ls_steps),
        "planeb_shape": {"chiplets": args.chiplets,
                         "prompt_len": args.planeb_prompt_len,
                         "batch": args.planeb_batch},
    }
    check_schema(rec)
    os.makedirs(EXPERIMENTS, exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=1)

    emit([{"variant": k, **v} for k, v in results.items()], "spec_serving")
    emit(rec["planeb_sweep"], "spec_acceptance_sweep")
    emit([{"variant": k,
           "fabric_gb_per_token": v["fabric_gb_per_token"],
           "best_mu": v["best_mu"], "front_size": len(v["front"])}
          for k, v in rec["noi"].items()], "spec_noi")
    up = (results["spec8_k4"]["tokens_per_s"]
          / max(results["baseline"]["tokens_per_s"], 1e-9))
    print(f"spec8_k4 decode uplift: {up:.2f}x, acceptance "
          f"{results['spec8_k4']['spec_acceptance']}, -> {args.out}")


if __name__ == "__main__":
    main()
