"""Analytical latency/energy/EDP simulator for the chiplet architectures (§4).

Execution model (2.5D-HI, §4.2): attention phases run on the SM cluster fed
by MC/DRAM; feed-forward runs on the ReRAM macro; MHA of layer l overlaps
FF of layer l-1 ("the SMs efficiently accelerate MHA computation, and the
ReRAM layer computes the FF layer in parallel"); GPT-J's parallel
formulation (eq. 9) overlaps them within one layer.  Phase times are
max(compute, DRAM streaming, busiest-NoI-link serialisation); energies are
unit busy-power × time + byte-hop NoI energy + DRAM access energy.

Calibration: exactly two scalars for 2.5D-HI (sm_efficiency, reram_fill)
fitted to its two Table-4 anchors (BERT-Base/36 = 50 ms, GPT-J/100 =
143 ms), and two scalars per baseline (throughput eff + bank-parallelism
scale exponent) fitted to that baseline's own Table-4 row (340/975 ms
HAIMA, 210/1435 ms TransPIM); every other figure must *emerge*.  Fitted
values and residuals are reported in EXPERIMENTS.md §Paper-validation.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.core import chiplets as C
from repro.core.noi import NoIEval, evaluate_noi, noi_energy, noi_phase_time
from repro.core.placement import Placement, initial_placement
from repro.core.traffic import Phase, Workload, transformer_phases


@dataclasses.dataclass
class SimResult:
    arch: str
    workload: str
    n_chiplets: int
    seq_len: int
    latency_s: float
    energy_j: float
    per_kernel_s: dict
    noi: Optional[NoIEval] = None

    @property
    def edp(self) -> float:
        return self.latency_s * self.energy_j


@dataclasses.dataclass
class Calib:
    # Fitted by calibrate() to the Table-4 anchors (python -m repro.core.simulator);
    # residuals reported in EXPERIMENTS.md §Paper-validation.
    sm_efficiency: float = 0.011923    # fitted: 2.5D-HI anchors (50ms/143ms)
    reram_fill: float = 0.00029342     # fitted: 2.5D-HI anchors
    haima_eff: float = 0.0048701      # fitted to HAIMA_chiplet GPT-J anchor
    transpim_eff: float = 0.0045998   # fitted to TransPIM_chiplet GPT-J anchor
    # bank-parallelism scale exponents (dim-util curve shape), fitted to the
    # Table-4 GPT-J/100-chiplet row (975 ms / 1435 ms)
    haima_scale_exp: float = 1.2838
    transpim_scale_exp: float = 0.7141
    # originals: thermally-capped fraction of banks concurrently active
    orig_bank_cap: float = 0.25        # 4-of-16 banks (§4.3 thermal argument)


CALIB = Calib()


def _alloc(n_chiplets: int) -> dict:
    return dict(C.SYSTEM_ALLOC[n_chiplets])


def _phase_noi_times(placement: Placement, phases: list[Phase]) -> tuple[list[float], NoIEval]:
    ev = evaluate_noi(placement, phases)
    times = []
    for u in ev.per_phase_link_bytes:
        times.append(noi_phase_time(u))
    if not times:
        times = [0.0] * len(phases)
    return times, ev


def _energy(phases, times_by_phase, alloc, noi_ev, busy: dict) -> float:
    """busy: phase-name -> set of busy unit types."""
    e = 0.0
    total_t = sum(times_by_phase.values())
    unit_power = {
        "SM": alloc.get("SM", 0) * C.SM.power_w,
        "MC": alloc.get("MC", 0) * C.MC.power_w,
        "ReRAM": alloc.get("ReRAM", 0) * C.RERAM.power_w,
        "SRAM": alloc.get("SRAM", 0) * 1.2,
        "ACU": alloc.get("ACU", 0) * 0.9,
        "HOST": alloc.get("HOST", 0) * 6.0,
        # DRAM-PIM chiplet actively computing (Aquabolt-XL-class in-bank
        # logic [26]) — distinct from the idle/background term below.
        "DRAM": alloc.get("DRAM", 0) * 1.3,
    }
    for ph in phases:
        t = times_by_phase.get(ph.name, 0.0) * ph.repeat
        for unit in busy.get(ph.name, ()):  # busy power
            e += unit_power.get(unit, 0.0) * t
        e += (ph.dram_bytes * ph.repeat) * 8 * C.DRAM.energy_pj_per_bit * 1e-12
    e += alloc.get("DRAM", 0) * C.DRAM.idle_power_w * total_t  # DRAM background
    if noi_ev is not None:
        e += noi_energy(noi_ev)
    return e


# ---------------------------------------------------------------------------
# 2.5D-HI
# ---------------------------------------------------------------------------

def simulate_2p5d_hi(w: Workload, n_chiplets: int, *,
                     placement: Optional[Placement] = None,
                     calib: Calib = CALIB) -> SimResult:
    alloc = _alloc(n_chiplets)
    placement = placement or initial_placement(n_chiplets)
    phases = transformer_phases(w)
    by_name = {p.name: p for p in phases}
    noi_t, ev = _phase_noi_times(placement, phases)
    noi_by = {p.name: t for p, t in zip(phases, noi_t)}

    dram_bw = alloc["DRAM"] * C.DRAM.bw

    # Dimensional utilisation (structural, NOT fitted): achieved fraction of
    # peak grows ~linearly with the stationary operand dimension until the
    # pipeline saturates — fill/drain overhead of the tensor-core pipeline
    # (SM) and of crossbar column groups (ReRAM) is amortised over the
    # contracted dim.  Saturation points: 4096 (SM, Volta pipeline depth ×
    # MMA tile) and 16384 (ReRAM, 128 crossbar columns × 128-wide tiles).
    # The paper's own Table-4 anchors imply this (~1% util @ d=768 vs ~4%
    # @ d=4096); the two calib scalars set the *level*, this sets the shape.
    def sm_rate(dim):
        return (alloc["SM"] * C.SM.peak_flops * calib.sm_efficiency
                * min(1.0, dim / C.SM_SAT_DIM))

    def rer_rate(dim):
        # Weight duplication (§4.1.1) keeps the macro full regardless of
        # the stationary matrix's width: copies of the weights are
        # parallelised across idle crossbars ("prevents any
        # underutilization of ReRAM chiplets"), so — unlike the SM plane —
        # ReRAM throughput is dim-independent; ``reram_fill`` captures the
        # pipeline fill/drain share alone.
        del dim
        return alloc["ReRAM"] * C.RERAM.peak_flops * calib.reram_fill

    def t_attn(name, dim=w.d_model):
        p = by_name[name]
        return max(p.sm_flops / sm_rate(dim),
                   p.dram_bytes / dram_bw,
                   noi_by[name])

    def t_reram(name, dim):
        p = by_name[name]
        return max(p.reram_flops / rer_rate(dim), noi_by[name])

    t_embed = t_reram("embed", w.d_model)
    stage_attn = t_attn("kqv") + t_attn("score")
    if "cross" in by_name:
        stage_attn += t_attn("cross") * by_name["cross"].repeat / max(w.n_layers, 1)
    stage_ff = t_reram("ff", w.d_ff)
    t_head = t_reram("lm_head", min(w.vocab, C.RERAM_SAT_DIM))

    k = w.n_layers
    if w.parallel_mha_ff:  # eq. 9: overlap within the layer
        total = t_embed + k * max(stage_attn, stage_ff) + t_head
    else:  # software pipeline: FF(l-1) under MHA(l)
        total = (t_embed + stage_attn + (k - 1) * max(stage_attn, stage_ff)
                 + stage_ff + t_head)

    per_kernel = {"embed": t_embed, "kqv": t_attn("kqv") * k,
                  "score": t_attn("score") * k, "ff": stage_ff * k,
                  "lm_head": t_head}
    times = {"embed": t_embed, "kqv": t_attn("kqv"), "score": t_attn("score"),
             "ff": stage_ff, "lm_head": t_head}
    if "cross" in by_name:
        times["cross"] = t_attn("cross")
        per_kernel["cross"] = t_attn("cross") * by_name["cross"].repeat
    busy = {"embed": {"ReRAM"}, "kqv": {"SM", "MC"}, "score": {"SM", "MC"},
            "cross": {"SM", "MC"}, "ff": {"ReRAM", "MC"}, "lm_head": {"ReRAM"}}
    energy = _energy(phases, times, alloc, ev, busy)
    return SimResult("2.5D-HI", w.name, n_chiplets, w.seq_len, total, energy,
                     per_kernel, ev)


# ---------------------------------------------------------------------------
# calibration (§4 Table-4 anchors; see DESIGN.md §6)
# ---------------------------------------------------------------------------

# Table 4 anchors (ms): the ONLY numbers any free scalar is fitted to.
ANCHORS = {
    "2.5D-HI": (("bert-base", 64, 36, 50.0), ("gpt-j", 64, 100, 143.0)),
    "HAIMA_chiplet": (("bert-base", 64, 36, 340.0),
                      ("gpt-j", 64, 100, 975.0)),
    "TransPIM_chiplet": (("bert-base", 64, 36, 210.0),
                         ("gpt-j", 64, 100, 1435.0)),
}


def _hi_residual(calib: Calib, workloads: dict) -> float:
    r = 0.0
    for arch, n, chips, target_ms in ANCHORS["2.5D-HI"]:
        res = simulate_2p5d_hi(workloads[(arch, n)], chips, calib=calib)
        r += math.log(res.latency_s * 1e3 / target_ms) ** 2
    return r


def calibrate(verbose: bool = False) -> Calib:
    """Fit the free scalars to the Table-4 anchors.

    2.5D-HI: 2 scalars (sm_efficiency, reram_fill) ↔ 2 anchors —
    coarse→fine log-grid search.  Each baseline: 1 throughput scalar ↔ its
    own 36-chiplet anchor — log-bisection (latency is monotone in the
    scalar).  Everything else in Plane B stays at its Table-1 value.
    """
    from repro.config import get_config

    workloads = {(a, n): Workload.from_config(get_config(a), seq_len=n)
                 for a, n, _, _ in (ANCHORS["2.5D-HI"]
                                    + ANCHORS["HAIMA_chiplet"]
                                    + ANCHORS["TransPIM_chiplet"])}

    # --- 2.5D-HI: 2-D log-grid, 3 refinement rounds ----------------------
    lo = (math.log(1e-4), math.log(1e-4))
    hi = (math.log(1.0), math.log(1.0))
    best = (float("inf"), None)
    for _round in range(4):
        g0 = [lo[0] + (hi[0] - lo[0]) * i / 23 for i in range(24)]
        g1 = [lo[1] + (hi[1] - lo[1]) * i / 23 for i in range(24)]
        for a in g0:
            for b in g1:
                c = dataclasses.replace(CALIB, sm_efficiency=math.exp(a),
                                        reram_fill=math.exp(b))
                r = _hi_residual(c, workloads)
                if r < best[0]:
                    best = (r, (a, b))
        (a, b) = best[1]
        da = (hi[0] - lo[0]) / 23
        db = (hi[1] - lo[1]) / 23
        lo, hi = (a - da, b - db), (a + da, b + db)
    sm_eff, fill = math.exp(best[1][0]), math.exp(best[1][1])

    # --- baselines: 2 scalars ↔ 2 anchors each ----------------------------
    # The GPT-J anchor pins the throughput eff (its kqv/ff dims saturate the
    # util curve, so the exponent is inert there); the BERT anchor then pins
    # the bank-parallelism scale exponent.
    def fit_baseline(sim_fn, eff_field: str, exp_field: str, anchors):
        bert_anchor, gptj_anchor = anchors

        def latency_ms(eff, exp, anchor):
            arch, n, chips, _ = anchor
            c = dataclasses.replace(CALIB, **{eff_field: eff, exp_field: exp})
            return sim_fn(workloads[(arch, n)], chips, calib=c).latency_s * 1e3

        lo_e, hi_e = 1e-6, 1.0            # eff ↔ GPT-J (decreasing)
        for _ in range(60):
            mid = math.sqrt(lo_e * hi_e)
            if latency_ms(mid, 1.0, gptj_anchor) > gptj_anchor[3]:
                lo_e = mid
            else:
                hi_e = mid
        eff = math.sqrt(lo_e * hi_e)

        lo_x, hi_x = 0.2, 4.0             # exp ↔ BERT (increasing)
        for _ in range(60):
            mid = 0.5 * (lo_x + hi_x)
            if latency_ms(eff, mid, bert_anchor) < bert_anchor[3]:
                lo_x = mid
            else:
                hi_x = mid
        return eff, 0.5 * (lo_x + hi_x)

    from repro.core import baselines as B  # local import (module cycle)
    haima_eff, haima_exp = fit_baseline(
        B.simulate_haima_chiplet, "haima_eff", "haima_scale_exp",
        ANCHORS["HAIMA_chiplet"])
    transpim_eff, transpim_exp = fit_baseline(
        B.simulate_transpim_chiplet, "transpim_eff", "transpim_scale_exp",
        ANCHORS["TransPIM_chiplet"])

    fitted = Calib(sm_efficiency=sm_eff, reram_fill=fill,
                   haima_eff=haima_eff, transpim_eff=transpim_eff,
                   haima_scale_exp=haima_exp, transpim_scale_exp=transpim_exp,
                   orig_bank_cap=CALIB.orig_bank_cap)
    if verbose:
        print(f"fitted: sm_efficiency={sm_eff:.5g} reram_fill={fill:.5g} "
              f"haima_eff={haima_eff:.5g} haima_scale_exp={haima_exp:.4f} "
              f"transpim_eff={transpim_eff:.5g} "
              f"transpim_scale_exp={transpim_exp:.4f}")
        for arch, n, chips, target in ANCHORS["2.5D-HI"]:
            res = simulate_2p5d_hi(workloads[(arch, n)], chips, calib=fitted)
            print(f"  2.5D-HI {arch} n={n} {chips}c: {res.latency_s*1e3:.1f} ms "
                  f"(anchor {target})")
        for name, fn in (("HAIMA_chiplet", B.simulate_haima_chiplet),
                         ("TransPIM_chiplet", B.simulate_transpim_chiplet)):
            for arch, n, chips, target in ANCHORS[name]:
                res = fn(workloads[(arch, n)], chips, calib=fitted)
                print(f"  {name} {arch} n={n} {chips}c: "
                      f"{res.latency_s*1e3:.1f} ms (anchor {target})")
    return fitted


if __name__ == "__main__":
    calibrate(verbose=True)
