"""Shared atomic-checkpoint core (repro.ckpt): dtype-safe npz, integrity
digests, atomic directory commits, and transient-failure retry."""
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import (DTYPE_KEY, atomic_save_dir, digest_arrays,
                        flatten_tree, gc_dirs, list_snapshots, load_arrays,
                        read_latest, retry, save_arrays, unflatten_tree)


def test_bf16_npz_roundtrip_is_bit_exact(tmp_path):
    """np.savez silently stores ml_dtypes bfloat16 as opaque void records;
    save_arrays/load_arrays must round-trip the true dtype and bits."""
    rng = np.random.default_rng(0)
    a16 = jnp.asarray(rng.standard_normal((3, 5)), jnp.bfloat16)
    arrays = {"bf16": np.asarray(a16),
              "i8": rng.integers(-128, 127, (4,)).astype(np.int8),
              "f32": rng.standard_normal((2, 2)).astype(np.float32)}
    path = os.path.join(tmp_path, "arrs.npz")
    save_arrays(path, arrays)
    back = load_arrays(path)
    assert set(back) == set(arrays)
    for k in arrays:
        assert back[k].dtype == arrays[k].dtype, k
        assert back[k].tobytes() == arrays[k].tobytes(), k


def test_reserved_dtype_key_rejected(tmp_path):
    with pytest.raises(ValueError, match="reserved"):
        save_arrays(os.path.join(tmp_path, "x.npz"),
                    {DTYPE_KEY: np.zeros(1)})


def test_digest_detects_corruption():
    arrays = {"a": np.arange(6, dtype=np.float32).reshape(2, 3)}
    d0 = digest_arrays(arrays)
    flipped = {"a": arrays["a"].copy()}
    flipped["a"][0, 0] += 1
    assert digest_arrays(flipped) != d0
    # same bytes under a different dtype/shape must not collide
    assert digest_arrays({"a": arrays["a"].view(np.int32)}) != d0
    assert digest_arrays(arrays, extra="meta") != d0


def test_atomic_save_dir_commit_and_latest(tmp_path):
    root = str(tmp_path)

    def write(tmp):
        with open(os.path.join(tmp, "payload"), "w") as f:
            f.write("v1")

    path = atomic_save_dir(root, "snap_00000000", write, prefix="snap_")
    assert os.path.isdir(path)
    assert read_latest(root) == "snap_00000000"

    # a writer that dies mid-flight leaves the previous commit untouched
    def boom(tmp):
        raise OSError("disk full")

    with pytest.raises(OSError):
        atomic_save_dir(root, "snap_00000001", boom, prefix="snap_")
    assert read_latest(root) == "snap_00000000"
    assert list_snapshots(root, "snap_") == ["snap_00000000"]


def test_gc_keeps_newest_and_protects(tmp_path):
    root = str(tmp_path)
    for i in range(5):
        atomic_save_dir(root, f"snap_{i:08d}", lambda t: None,
                        prefix="snap_")
    gc_dirs(root, "snap_", keep=2, protect="snap_00000000")
    names = list_snapshots(root, "snap_")
    assert names == ["snap_00000000", "snap_00000003", "snap_00000004"]


def test_list_snapshots_missing_root(tmp_path):
    assert list_snapshots(os.path.join(tmp_path, "nope"), "snap_") == []
    assert read_latest(os.path.join(tmp_path, "nope")) is None


def test_retry_backoff_and_exhaustion():
    sleeps = []
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise OSError("transient")
        return "ok"

    assert retry(flaky, retries=4, backoff_s=0.05,
                 sleep=sleeps.append) == "ok"
    assert sleeps == [0.05, 0.1]          # exponential backoff

    calls["n"] = -100                     # always fails within budget
    with pytest.raises(OSError, match="transient"):
        retry(flaky, retries=2, backoff_s=0.01, sleep=sleeps.append)


def test_flatten_unflatten_roundtrip_and_mismatches():
    tree = {"a": [np.arange(3, dtype=np.int32),
                  np.ones((2, 2), np.float32)],
            "b": {"c": np.asarray(jnp.zeros((2,), jnp.bfloat16))}}
    flat = flatten_tree(tree)
    assert set(flat) == {"a/0", "a/1", "b/c"}
    back = unflatten_tree(tree, flat, cast=False)
    assert np.asarray(back["b"]["c"]).dtype == np.asarray(tree["b"]["c"]).dtype
    # cast=True coerces to the template dtype, cast=False keeps stored
    stored = dict(flat)
    stored["a/1"] = flat["a/1"].astype(np.float64)
    assert np.asarray(unflatten_tree(tree, stored)["a"][1]).dtype \
        == np.float32
    assert np.asarray(unflatten_tree(tree, stored,
                                     cast=False)["a"][1]).dtype == np.float64
    with pytest.raises(KeyError, match="missing leaf"):
        unflatten_tree(tree, {k: v for k, v in flat.items() if k != "a/0"})
    bad = dict(flat)
    bad["a/0"] = np.arange(4, dtype=np.int32)
    with pytest.raises(ValueError, match="shape"):
        unflatten_tree(tree, bad)
