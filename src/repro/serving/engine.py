"""Batched serving engine with a slotted KV cache and continuous batching.

The paper's evaluation is *inference*; this is the inference runtime for
Plane A.  Design follows the production pattern (vLLM/TGI-style, expressed
in JAX with static shapes):

- a fixed pool of ``max_batch`` KV slots, each ``kv_len`` tokens deep
  (static shapes → one compiled decode step, no recompilation as requests
  come and go);
- **continuous batching**: finished requests free their slot immediately
  and a queued request is prefilled into it while other slots keep
  decoding — the decode step always runs over the full slot pool with a
  validity mask;
- **fused decode fast path** (default): one jitted, cache-donated function
  does decode → sample (greedy and temperature, PRNG threaded on device) →
  position/budget/EOS bookkeeping, and the only device→host traffic per
  iteration is one packed ``(2, max_batch)`` int32 array of
  ``(next_token, done)`` — the serving analogue of the paper keeping the
  attention dataflow on the fast side of the interconnect (§3.2).
  Donation lets XLA update the KV pool in place instead of copying it
  every token;
- prefill is fused with slot insertion: one jitted, cache-donated call runs
  the prompt forward pass, samples the first token on device, and inserts
  the prefill cache into the pool via ``dynamic_update_slice``.  Prompts
  are right-padded to bucketed lengths (causal masking keeps the logits
  exact) so admission does not retrace per prompt length;
- ``fused=False`` preserves the original host-looped step (host argmax,
  per-slot Python bookkeeping, non-donated cache) as the measurement
  baseline for ``benchmarks/perf_serving.py``;
- greedy or temperature sampling, per-request max-token budget.

The engine is mesh-aware: pass ``mesh=`` to shard the slot pool (and run
the decode step) over a pod with the decode-mode plan from
``repro.parallel.sharding``; on CPU tests everything runs on one device
with the same code path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import transformer as T
from repro.parallel.api import activate_plan


@dataclasses.dataclass
class EngineConfig:
    max_batch: int = 8            # KV slot pool size
    kv_len: int = 256             # per-slot KV depth
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 → greedy
    eos_token: int = -1           # -1 → never stops early
    impl: str = "ref"             # attention impl ("flash" → Pallas decode)
    seed: int = 0
    fused: bool = True            # zero-host-sync decode step (False = seed path)
    decode_chunk: int = 1         # device decode iterations per step() —
    #   >1 runs a lax.scan of decode→sample on device (multi-step
    #   scheduling): host sync cost is amortised over the chunk, at the
    #   price of admitting new requests only at chunk boundaries


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                       # (prompt_len,) int32
    max_new_tokens: Optional[int] = None
    # -- filled by the engine -------------------------------------------------
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


# prompt-length buckets: one prefill compile per bucket, not per length
_MIN_BUCKET = 8


def _bucket_len(plen: int, kv_len: int) -> int:
    b = _MIN_BUCKET
    while b < plen:
        b *= 2
    return min(b, kv_len)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, ecfg: Optional[EngineConfig] = None,
                 *, mesh=None):
        # NOTE: default built per-instance — a dataclass default argument
        # would be one shared mutable EngineConfig across all engines.
        self.cfg, self.params = cfg, params
        self.ecfg = ecfg = ecfg if ecfg is not None else EngineConfig()
        B, S = ecfg.max_batch, ecfg.kv_len
        self.cache = T.init_cache(cfg, B, S, dtype=jnp.bfloat16)
        self.slot_req: list[Optional[Request]] = [None] * B
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self._uid = 0

        # host-transfer accounting (benchmarks/perf_serving.py)
        self.host_transfers = 0
        self.host_bytes = 0
        self.decode_steps = 0

        # prompt-length bucketing is exact only when cache index == token
        # position for every self-attention cache (causal masking hides the
        # padded tail, and the decode write at ``pos`` overwrites the pad
        # entry).  Ring-buffer (local-window) caches would evict real
        # entries and SSM/recurrent state integrates the pads — those
        # configs prefill at exact length (one compile per distinct length).
        self._bucketed = all(k in ("global", "cross") for k in cfg.layer_kinds)

        # optional decode-mode sharding plan for the slot pool
        self._plan = None
        if mesh is not None:
            from repro.parallel.sharding import cache_shardings, serving_decode_plan
            self._plan, ctx = serving_decode_plan(cfg, mesh, max_batch=B,
                                                  kv_len=S)
            shardings = cache_shardings(
                jax.eval_shape(lambda: self.cache), ctx)
            self.cache = jax.device_put(self.cache, shardings)

        # -- fused path: device-resident per-slot state ----------------------
        self._state = {
            "tokens": jnp.zeros((B,), jnp.int32),
            "pos": jnp.zeros((B,), jnp.int32),
            "budget": jnp.zeros((B,), jnp.int32),
            "live": jnp.zeros((B,), bool),
            "key": jax.random.PRNGKey(ecfg.seed),
        }
        self._jit_step = jax.jit(self._fused_step_fn, donate_argnums=(1, 2))
        self._jit_prefill_insert = jax.jit(self._prefill_insert_fn,
                                           donate_argnums=(1, 2))

        # -- seed-compat path (fused=False) ----------------------------------
        self._key = jax.random.PRNGKey(ecfg.seed)
        self._jit_decode = jax.jit(self._decode_fn)
        self._jit_prefill = jax.jit(self._prefill_fn)
        self._jit_insert = jax.jit(self._insert_fn, donate_argnums=(0,))

    # -- device→host choke point ---------------------------------------------
    def _fetch(self, x) -> np.ndarray:
        """The engine's single device→host transfer point (explicit, so
        tests can fence everything else with a d2h transfer guard)."""
        arr = jax.device_get(x)
        arr = np.asarray(arr)
        self.host_transfers += 1
        self.host_bytes += arr.nbytes
        return arr

    # -- jitted cores: fused path ---------------------------------------------
    def _sample_dev(self, logits, key):
        if self.ecfg.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32), key
        key, sub = jax.random.split(key)
        nxt = jax.random.categorical(sub, logits / self.ecfg.temperature,
                                     axis=-1)
        return nxt.astype(jnp.int32), key

    def _fused_step_fn(self, params, cache, state):
        """decode → sample → bookkeeping, all on device.  Runs
        ``decode_chunk`` iterations (lax.scan for >1) and returns the new
        (cache, state) plus a packed (K, 2, B) int32 of (next_token | -1,
        done) — the only array the host reads back per step."""
        def one(carry, _):
            cache, state = carry
            logits, cache = T.decode_step(params, self.cfg, cache,
                                          state["tokens"], state["pos"],
                                          impl=self.ecfg.impl)
            nxt, key = self._sample_dev(logits, state["key"])
            live = state["live"]
            pos_new = jnp.where(live, state["pos"] + 1, state["pos"])
            budget_new = jnp.where(live, state["budget"] - 1, state["budget"])
            done = (budget_new <= 0) | (pos_new >= self.ecfg.kv_len)
            if self.ecfg.eos_token >= 0:
                done = done | (nxt == self.ecfg.eos_token)
            done = live & done
            packed = jnp.stack([jnp.where(live, nxt, -1),
                                done.astype(jnp.int32)])
            state = {
                "tokens": jnp.where(live, nxt, state["tokens"]),
                "pos": pos_new,
                "budget": budget_new,
                "live": live & ~done,
                "key": key,
            }
            return (cache, state), packed

        with activate_plan(self._plan):
            chunk = max(1, self.ecfg.decode_chunk)
            if chunk == 1:
                (cache, state), packed = one((cache, state), None)
                packed = packed[None]
            else:
                (cache, state), packed = jax.lax.scan(
                    one, (cache, state), None, length=chunk)
        return cache, state, packed

    def _prefill_insert_fn(self, params, cache, state, tokens, slot, length,
                           budget):
        """prompt forward pass → first-token sample → slot insert → state
        update, one jitted cache-donated call per admission."""
        with activate_plan(self._plan):
            logits, pcache = T.prefill(params, self.cfg, {"tokens": tokens},
                                       impl=self.ecfg.impl,
                                       kv_cap=self.ecfg.kv_len, length=length)
            nxt, key = self._sample_dev(logits, state["key"])
            tok = nxt[0]
            cache = self._insert_fn(cache, pcache, slot, length)
            state = {
                "tokens": state["tokens"].at[slot].set(tok),
                "pos": state["pos"].at[slot].set(length),
                "budget": state["budget"].at[slot].set(budget - 1),
                "live": state["live"].at[slot].set(budget > 1),
                "key": key,
            }
        return cache, state, tok

    def _insert_fn(self, cache, pcache, slot, length):
        """Insert a batch-1 prefill cache into slot ``slot`` of the pool
        with one ``dynamic_update_slice`` per leaf (batch axis is axis 1 of
        every stacked leaf).  When prompts are bucket-padded, ``pos`` leaves
        beyond ``length`` are invalidated so pad entries never attend."""
        bucketed = self._bucketed

        def ins(path, pool, one):
            one = one.astype(pool.dtype)
            if bucketed and str(getattr(path[-1], "key", "")) == "pos":
                idx = jnp.arange(one.shape[-1], dtype=jnp.int32)
                one = jnp.where(idx[None, None, :] < length, one, -1)
            start = (0, slot) + (0,) * (one.ndim - 2)
            return jax.lax.dynamic_update_slice(pool, one, start)

        return jax.tree_util.tree_map_with_path(ins, cache, pcache)

    # -- jitted cores: seed-compat path ---------------------------------------
    def _decode_fn(self, params, cache, tokens, pos):
        logits, cache = T.decode_step(params, self.cfg, cache, tokens, pos,
                                      impl=self.ecfg.impl)
        return logits, cache

    def _prefill_fn(self, params, tokens, length):
        # single-request prefill padded to a bucketed length (static shape)
        logits, cache = T.prefill(params, self.cfg, {"tokens": tokens},
                                  impl=self.ecfg.impl, kv_cap=self.ecfg.kv_len,
                                  length=length)
        return logits, cache

    # -- public API -------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new_tokens: Optional[int] = None) -> Request:
        req = Request(uid=self._uid, prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, t_enqueue=time.time())
        self._uid += 1
        self.queue.append(req)
        return req

    def step(self) -> int:
        """One engine iteration: admit queued requests into free slots
        (prefill), then one decode step over the slot pool.  Returns the
        number of live slots."""
        if self.ecfg.fused:
            return self._step_fused()
        return self._step_host()

    def _step_fused(self) -> int:
        self._admit_fused()
        if not any(r is not None for r in self.slot_req):
            return 0
        self.cache, self._state, packed = self._jit_step(
            self.params, self.cache, self._state)
        arr = self._fetch(packed)                 # ONE d2h transfer
        self.decode_steps += arr.shape[0]
        now = time.time()
        for it in range(arr.shape[0]):            # decode_chunk iterations
            for i, req in enumerate(self.slot_req):
                if req is None or arr[it, 0, i] < 0:
                    continue
                tok = int(arr[it, 0, i])
                if not req.output:
                    req.t_first_token = now
                req.output.append(tok)
                if arr[it, 1, i]:
                    req.done = True
                    req.t_done = now
                    self.finished.append(req)
                    self.slot_req[i] = None  # slot freed → continuous batching
        return sum(r is not None for r in self.slot_req)

    def _step_host(self) -> int:
        """Original per-token host round-trip step (measurement baseline)."""
        self._admit_host()
        live = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not live:
            return 0
        tokens = jnp.asarray(self._last_token)
        pos = jnp.asarray(self._slot_pos)
        logits, self.cache = self._jit_decode(self.params, self.cache,
                                              tokens, pos)
        self.decode_steps += 1
        nxt = self._sample(logits)
        now = time.time()
        for i in live:
            req = self.slot_req[i]
            tok = int(nxt[i])
            if not req.output:
                req.t_first_token = now
            req.output.append(tok)
            self._last_token[i] = tok
            self._slot_pos[i] += 1
            self._slot_budget[i] -= 1
            hit_eos = (self.ecfg.eos_token >= 0 and tok == self.ecfg.eos_token)
            if self._slot_budget[i] <= 0 or hit_eos or \
                    self._slot_pos[i] >= self.ecfg.kv_len:
                req.done = True
                req.t_done = now
                self.finished.append(req)
                self.slot_req[i] = None      # slot freed → continuous batching
        return sum(r is not None for r in self.slot_req)

    def run_until_drained(self, max_iters: int = 10_000) -> list[Request]:
        it = 0
        while (self.queue or any(r is not None for r in self.slot_req)):
            self.step()
            it += 1
            if it > max_iters:
                raise RuntimeError("engine did not drain")
        return self.finished

    # -- internals ---------------------------------------------------------------
    def _next_request(self, slot: int) -> Optional[tuple]:
        """Pop the next admissible queued request and its padded prompt, or
        None.  Requests asking for 0 tokens finish immediately."""
        if self.slot_req[slot] is not None:
            return None
        while self.queue:
            req = self.queue.pop(0)
            # a request may ask for fewer tokens than the engine default —
            # including 0 (`or` would silently swap in the default)
            budget = req.max_new_tokens if req.max_new_tokens is not None \
                else self.ecfg.max_new_tokens
            if budget <= 0:
                req.done = True
                req.t_first_token = req.t_done = time.time()
                self.finished.append(req)
                continue
            plen = len(req.prompt)
            if plen + 1 >= self.ecfg.kv_len:
                raise ValueError(f"prompt ({plen}) ≥ kv_len ({self.ecfg.kv_len})")
            pad = _bucket_len(plen, self.ecfg.kv_len) if self._bucketed else plen
            toks = np.zeros((1, pad), np.int32)
            toks[0, :plen] = req.prompt
            return req, toks, plen, budget
        return None

    def _admit_fused(self):
        for slot in range(self.ecfg.max_batch):
            nxt = self._next_request(slot)
            if nxt is None:
                continue
            req, toks, plen, budget = nxt
            self.cache, self._state, first = self._jit_prefill_insert(
                self.params, self.cache, self._state, jnp.asarray(toks),
                jnp.int32(slot), jnp.int32(plen), jnp.int32(budget))
            tok = int(self._fetch(first))
            req.output = [tok]
            req.t_first_token = time.time()
            if budget == 1:         # the prefill sample was the whole budget
                req.done = True
                req.t_done = req.t_first_token
                self.finished.append(req)
            else:
                self.slot_req[slot] = req

    def _admit_host(self):
        if not hasattr(self, "_slot_pos"):
            B = self.ecfg.max_batch
            self._slot_pos = np.zeros(B, np.int32)
            self._slot_budget = np.zeros(B, np.int32)
            self._last_token = np.zeros(B, np.int32)
        for slot in range(self.ecfg.max_batch):
            nxt = self._next_request(slot)
            if nxt is None:
                continue
            req, toks, plen, budget = nxt
            logits, pcache = self._jit_prefill(
                self.params, jnp.asarray(toks), jnp.int32(plen))
            self.cache = self._jit_insert(self.cache, pcache, jnp.int32(slot),
                                          jnp.int32(plen))
            first = self._sample(logits)
            req.output = [int(first[0])]
            req.t_first_token = time.time()
            if budget == 1:         # the prefill sample was the whole budget
                req.done = True
                req.t_done = req.t_first_token
                self.finished.append(req)
                continue
            self.slot_req[slot] = req
            self._slot_pos[slot] = plen
            self._slot_budget[slot] = budget - 1
            self._last_token[slot] = int(first[0])

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.ecfg.temperature <= 0.0:
            return self._fetch(jnp.argmax(logits, axis=-1))
        self._key, sub = jax.random.split(self._key)
        return self._fetch(jax.random.categorical(
            sub, logits / self.ecfg.temperature, axis=-1))

    # -- stats ---------------------------------------------------------------
    def stats(self) -> dict:
        done = self.finished
        if not done:
            return {"finished": 0}
        lat = [r.t_done - r.t_enqueue for r in done]
        ttft = [r.t_first_token - r.t_enqueue for r in done]
        toks = sum(len(r.output) for r in done)
        span = max(r.t_done for r in done) - min(r.t_enqueue for r in done)
        return {
            "finished": len(done),
            "tokens": toks,
            "tokens_per_s": toks / max(span, 1e-9),
            "mean_latency_s": float(np.mean(lat)),
            "mean_ttft_s": float(np.mean(ttft)),
            "decode_steps": self.decode_steps,
            "host_transfers": self.host_transfers,
            "host_bytes": self.host_bytes,
            "host_bytes_per_token": self.host_bytes / max(toks, 1),
        }
